//! `vhpc perf` — the large-trace throughput harness.
//!
//! Drives the canonical open-loop multi-tenant trace (up to a million
//! arrivals over 100k tenants, `--machines`-many nodes) through the
//! sharded control plane and reports wall-clock throughput alongside
//! the determinism witnesses the rest of the suite pins:
//!
//! 1. **arrivals** — synthesize the full arrival stream standalone
//!    (`tenancy/arrivals.rs`), bounded by the same virtual horizon the
//!    cluster phase uses, timing fixed-size chunks so the phase gets
//!    real latency percentiles, and fingerprint it.
//! 2. **engine** — a head-to-head microbench of the calendar-queue
//!    [`Engine`](crate::sim::Engine) against the boxed-closure
//!    [`ClosureHeapEngine`](crate::sim::ClosureHeapEngine) it replaced,
//!    on an identical seeded hop workload (same delays, same event
//!    count, asserted equal) — the speedup figure the rewrite is
//!    accountable for.
//! 3. **cluster** — the full sharded control-plane run
//!    ([`run_sharded_tenants`](crate::cluster::shard::run_sharded_tenants)):
//!    events/sec end to end, plus the merged counter fingerprint that
//!    must not move when the engine gets faster.
//!
//! The CLI (`cli.rs`) renders the outcome as `BENCH_perf.json` and can
//! gate against a committed baseline (`--baseline F --gate PCT`),
//! failing the run when events/sec regresses past the threshold.
//!
//! Wall-clock readings live only in the reported stats — nothing the
//! simulation computes depends on them, so the virtual-time results
//! and every fingerprint stay deterministic.

use crate::cluster::policy::SchedulePolicy;
use crate::cluster::shard::{run_sharded_tenants, ShardRunConfig};
use crate::config::ClusterSpec;
use crate::sim::{ClosureHeapEngine, Engine, SimEvent, SimTime};
use crate::tenancy::{
    stream_fingerprint, ArrivalGen, JobArrival, PopulationSpec, TenantQuotas,
};
use std::collections::BTreeMap;
use std::time::Instant;

/// Arrivals per timing chunk in the synthesis phase.
const ARRIVAL_CHUNK: usize = 8192;
/// Interleaved timing rounds for the engine microbench.
const ENGINE_ROUNDS: usize = 4;
/// Initial walkers per engine-microbench round.
const ENGINE_WALKERS: u32 = 2048;
/// Reschedules per walker (so one round fires `WALKERS * (HOPS + 1)`).
const ENGINE_HOPS: u32 = 31;

/// Latency percentiles over one phase's timing samples, milliseconds.
#[derive(Debug, Clone, Copy)]
pub struct Percentiles {
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// Nearest-rank percentiles of `samples` (milliseconds). One sample
/// degenerates to that sample across the board; empty input reads 0.
pub fn percentiles(samples: &[f64]) -> Percentiles {
    if samples.is_empty() {
        return Percentiles { p50_ms: 0.0, p90_ms: 0.0, p99_ms: 0.0, max_ms: 0.0 };
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let at = |p: f64| {
        let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    };
    Percentiles {
        p50_ms: at(50.0),
        p90_ms: at(90.0),
        p99_ms: at(99.0),
        max_ms: sorted[sorted.len() - 1],
    }
}

/// One harness phase: what ran, how long, and its chunk latencies.
#[derive(Debug, Clone)]
pub struct PhaseStats {
    pub name: &'static str,
    /// Work units processed (arrivals, events fired, …).
    pub units: u64,
    pub wall_secs: f64,
    pub latency: Percentiles,
}

/// The engine microbench half of the harness.
#[derive(Debug, Clone, Copy)]
pub struct EngineBench {
    /// Events fired per engine (identical by construction, asserted).
    pub events: u64,
    pub calendar_events_per_sec: f64,
    pub heap_events_per_sec: f64,
    /// `calendar / heap` — the figure the calendar rewrite must keep
    /// above 1.0 (target: >= 2x on the large trace).
    pub speedup: f64,
}

/// Everything one `vhpc perf` run measured.
#[derive(Debug, Clone)]
pub struct PerfOutcome {
    pub jobs: usize,
    pub tenants: u64,
    pub machines: u32,
    pub shards: usize,
    pub seed: u64,
    /// Virtual seconds the arrival stream spans.
    pub duration_secs: u64,
    pub jobs_submitted: usize,
    pub jobs_completed: u64,
    /// Engine events fired by the cluster phase, all shards.
    pub events: u64,
    /// Cluster-phase events/sec — the headline (and gated) figure,
    /// always measured untraced.
    pub events_per_sec: f64,
    /// Events/sec of the traced rerun (0 when the harness ran without
    /// `--trace`).
    pub traced_events_per_sec: f64,
    /// `(untraced - traced) / untraced * 100` — positive when tracing
    /// costs throughput. The `ext_perf` bench gates this under 5%.
    pub trace_overhead_pct: f64,
    pub trace_events_written: u64,
    pub trace_events_dropped: u64,
    pub makespan_secs: f64,
    pub windows: u64,
    pub arrivals_fingerprint: u64,
    /// FNV-1a digest of the merged counter snapshot (same digest the
    /// other sharded CLI drivers print).
    pub counter_digest: u64,
    pub counters: BTreeMap<String, u64>,
    pub engine: EngineBench,
    pub phases: Vec<PhaseStats>,
    /// Per-phase wall-time breakdown from the scoped profiling timers
    /// (`policy_sort`, `wal_flush`, `gossip_tick`, `window_merge`,
    /// `jacobi_sweep`), captured over the cluster phase.
    pub profile: Vec<crate::obs::profiling::PhaseProfile>,
}

// ---------------------------------------------------------------------
// Phase 1: arrival-stream synthesis
// ---------------------------------------------------------------------

/// Synthesize every arrival `pop` emits before `duration_secs` of
/// virtual time, timing fixed-size chunks. This is the exact stream the
/// cluster phase will submit — the conductor's pump keeps pulling while
/// `at < horizon` and the generator emits in time order, so the same
/// cut here reproduces its log arrival for arrival (the fingerprints
/// are compared in [`run_perf_trace`]). Returns the stream and the
/// phase stats.
pub fn synth_arrivals(pop: PopulationSpec, duration_secs: u64) -> (Vec<JobArrival>, PhaseStats) {
    let horizon = SimTime::from_secs(duration_secs);
    let mut gen = ArrivalGen::new(pop);
    let mut log = Vec::new();
    let mut samples = Vec::new();
    let t0 = Instant::now();
    let mut next = gen.next();
    while next.at < horizon {
        let c0 = Instant::now();
        let mut pulled = 0;
        while pulled < ARRIVAL_CHUNK && next.at < horizon {
            log.push(std::mem::replace(&mut next, gen.next()));
            pulled += 1;
        }
        samples.push(c0.elapsed().as_secs_f64() * 1e3);
    }
    let stats = PhaseStats {
        name: "arrivals",
        units: log.len() as u64,
        wall_secs: t0.elapsed().as_secs_f64(),
        latency: percentiles(&samples),
    };
    (log, stats)
}

// ---------------------------------------------------------------------
// Phase 2: engine microbench (calendar queue vs boxed-closure heap)
// ---------------------------------------------------------------------

/// Advance the walker's private LCG (Knuth MMIX constants).
fn lcg(x: u64) -> u64 {
    x.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(1_442_695_040_888_963_407)
}

/// Map an LCG draw onto a delay that exercises every calendar path:
/// mostly sub-second (in-bucket appends), a band of minutes (ring
/// traversal), and a far tail (overflow map past the ring horizon).
fn hop_delay(draw: u64) -> SimTime {
    let pick = (draw >> 56) & 0xff;
    let spread = (draw >> 8) & 0xffff_ffff;
    if pick < 179 {
        // ~70%: 0..1s
        SimTime::from_nanos(spread % 1_000_000_000)
    } else if pick < 243 {
        // ~25%: 0..120s
        SimTime::from_nanos((spread % 120_000) * 1_000_000)
    } else {
        // ~5%: 0..2000s — far beyond the 512-bucket ring
        SimTime::from_millis(spread % 2_000_000)
    }
}

/// The typed-event walker: no allocation per hop.
struct Hop {
    rng: u64,
    hops_left: u32,
}

impl SimEvent<u64> for Hop {
    fn fire(self, fired: &mut u64, eng: &mut Engine<u64, Hop>) {
        *fired += 1;
        if self.hops_left > 0 {
            let rng = lcg(self.rng);
            eng.schedule_after(hop_delay(rng), Hop { rng, hops_left: self.hops_left - 1 });
        }
    }
}

/// The same walker as a recursive boxed closure on the reference heap.
fn heap_hop(fired: &mut u64, eng: &mut ClosureHeapEngine<u64>, rng: u64, hops_left: u32) {
    *fired += 1;
    if hops_left > 0 {
        let rng = lcg(rng);
        eng.schedule_after(hop_delay(rng), move |s, e| heap_hop(s, e, rng, hops_left - 1));
    }
}

fn seed_walker(seed: u64, i: u32) -> (u64, SimTime) {
    let rng = lcg(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (rng, hop_delay(rng))
}

fn run_calendar_round(seed: u64) -> (u64, f64) {
    let mut eng: Engine<u64, Hop> = Engine::new();
    let mut fired = 0u64;
    let t0 = Instant::now();
    for i in 0..ENGINE_WALKERS {
        let (rng, at) = seed_walker(seed, i);
        eng.schedule_at(at, Hop { rng, hops_left: ENGINE_HOPS });
    }
    eng.run_to_completion(&mut fired);
    (fired, t0.elapsed().as_secs_f64())
}

fn run_heap_round(seed: u64) -> (u64, f64) {
    let mut eng: ClosureHeapEngine<u64> = ClosureHeapEngine::new();
    let mut fired = 0u64;
    let t0 = Instant::now();
    for i in 0..ENGINE_WALKERS {
        let (rng, at) = seed_walker(seed, i);
        eng.schedule_at(at, move |s: &mut u64, e| heap_hop(s, e, rng, ENGINE_HOPS));
    }
    eng.run_to_completion(&mut fired);
    (fired, t0.elapsed().as_secs_f64())
}

/// Run the calendar engine and the reference heap over identical
/// seeded hop schedules (interleaved rounds so neither side benefits
/// from cache warm-up order) and compare events/sec. Returns the bench
/// plus per-engine phase stats. Panics if the two engines disagree on
/// the fired-event count — they execute the same schedule by
/// construction, so a mismatch is an ordering bug the differential
/// suite exists to catch.
pub fn bench_engines(seed: u64) -> (EngineBench, PhaseStats, PhaseStats) {
    let mut cal_events = 0u64;
    let mut heap_events = 0u64;
    let mut cal_secs = 0.0f64;
    let mut heap_secs = 0.0f64;
    let mut cal_samples = Vec::new();
    let mut heap_samples = Vec::new();
    for round in 0..ENGINE_ROUNDS {
        let rseed = seed ^ ((round as u64 + 1) << 32);
        let (hf, ht) = run_heap_round(rseed);
        heap_events += hf;
        heap_secs += ht;
        heap_samples.push(ht * 1e3);
        let (cf, ct) = run_calendar_round(rseed);
        cal_events += cf;
        cal_secs += ct;
        cal_samples.push(ct * 1e3);
        assert_eq!(
            cf, hf,
            "engine microbench diverged: calendar fired {cf}, heap fired {hf}"
        );
    }
    let cal_eps = cal_events as f64 / cal_secs.max(1e-9);
    let heap_eps = heap_events as f64 / heap_secs.max(1e-9);
    let bench = EngineBench {
        events: cal_events,
        calendar_events_per_sec: cal_eps,
        heap_events_per_sec: heap_eps,
        speedup: cal_eps / heap_eps.max(1e-9),
    };
    let cal_stats = PhaseStats {
        name: "engine_calendar",
        units: cal_events,
        wall_secs: cal_secs,
        latency: percentiles(&cal_samples),
    };
    let heap_stats = PhaseStats {
        name: "engine_heap",
        units: heap_events,
        wall_secs: heap_secs,
        latency: percentiles(&heap_samples),
    };
    (bench, cal_stats, heap_stats)
}

// ---------------------------------------------------------------------
// Phase 3: the sharded control-plane trace
// ---------------------------------------------------------------------

/// Shape `spec` into the perf fleet: `machines` nodes, fast boots, the
/// whole pool pre-provisioned (min = max) so throughput measures the
/// scheduler, not the autoscaler's ramp.
pub fn perf_spec(mut spec: ClusterSpec, machines: u32, seed: u64) -> ClusterSpec {
    spec.machines = machines.max(2);
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec.autoscale.max_nodes = spec.machines - 1;
    spec.autoscale.min_nodes = spec.autoscale.max_nodes;
    spec.seed = seed;
    spec
}

/// The population whose open-loop stream carries ~`jobs` arrivals in
/// `duration_secs` of virtual time over `tenants` tenants.
pub fn perf_population(jobs: usize, tenants: u64, seed: u64, duration_secs: u64) -> PopulationSpec {
    let mut pop = PopulationSpec::new(tenants, seed);
    pop.rate_per_sec = jobs as f64 / duration_secs.max(1) as f64;
    pop
}

/// Run the whole harness: arrival synthesis, the engine microbench,
/// then the sharded cluster trace. `duration_secs` is the virtual span
/// of the arrival stream (the drain deadline is 4x that).
pub fn run_perf_trace(
    mut spec: ClusterSpec,
    jobs: usize,
    tenants: u64,
    shards: usize,
    seed: u64,
    duration_secs: u64,
) -> Result<PerfOutcome, String> {
    // the gated figure is always measured untraced; a `--trace` path
    // requests a traced rerun afterwards so the overhead is a
    // like-for-like comparison of the same deterministic run
    let trace_path = spec.trace_path.take();
    let machines = spec.machines;
    let pop = perf_population(jobs, tenants, seed, duration_secs);
    let (stream, arrivals_stats) = synth_arrivals(pop, duration_secs);
    let arrivals_fingerprint = stream_fingerprint(&stream);
    drop(stream);

    let (engine, cal_stats, heap_stats) = bench_engines(seed);

    let cap_slots = spec.max_advertisable_slots();
    if cap_slots == 0 {
        return Err("cluster has no compute capacity (needs >= 2 machines)".into());
    }
    let warmup = (spec.autoscale.min_nodes * spec.slots_per_node).clamp(1, cap_slots);
    let cfg = ShardRunConfig {
        shards: shards.max(1),
        warmup_slots: warmup,
        deadline_secs: duration_secs.saturating_mul(4).max(3600),
        ..Default::default()
    };
    // hold the profiling session for the cluster phase only: the scoped
    // timers in the scheduler/WAL/shard paths light up here and nowhere
    // else, and the lock keeps parallel perf tests from cross-draining
    let profiling_session = crate::obs::profiling::session();
    crate::obs::profiling::enable();
    let t0 = Instant::now();
    let o = run_sharded_tenants(
        spec.clone(),
        pop,
        SchedulePolicy::fairshare(),
        TenantQuotas::default(),
        duration_secs,
        &cfg,
    )
    .map_err(|e| e.to_string());
    let cluster_secs = t0.elapsed().as_secs_f64().max(1e-9);
    // drain before propagating any error so ENABLED never leaks on
    let profile = crate::obs::profiling::drain();
    drop(profiling_session);
    let o = o?;
    if o.arrivals_fingerprint != arrivals_fingerprint {
        return Err(format!(
            "arrival stream diverged between synthesis ({arrivals_fingerprint:016x}) \
             and the cluster run ({:016x})",
            o.arrivals_fingerprint
        ));
    }
    let events_per_sec = o.events as f64 / cluster_secs;
    let cluster_stats = PhaseStats {
        name: "cluster",
        units: o.events,
        wall_secs: cluster_secs,
        latency: percentiles(&[cluster_secs * 1e3]),
    };
    let mut phases = vec![arrivals_stats, cal_stats, heap_stats, cluster_stats];

    // the traced rerun: identical spec + stream, trace bus on. Its
    // counter fingerprint must byte-match the untraced run's — the
    // fingerprint-neutrality witness at perf scale.
    let (traced_eps, overhead_pct, tr_written, tr_dropped) = match trace_path {
        Some(path) => {
            spec.trace_path = Some(path);
            let t1 = Instant::now();
            let tr = run_sharded_tenants(
                spec,
                pop,
                SchedulePolicy::fairshare(),
                TenantQuotas::default(),
                duration_secs,
                &cfg,
            )
            .map_err(|e| e.to_string())?;
            let traced_secs = t1.elapsed().as_secs_f64().max(1e-9);
            if tr.fingerprint != o.fingerprint {
                return Err(format!(
                    "traced rerun drifted: counter digest {:016x} vs untraced {:016x}",
                    fingerprint_digest(&tr.fingerprint),
                    fingerprint_digest(&o.fingerprint)
                ));
            }
            let traced_eps = tr.events as f64 / traced_secs;
            phases.push(PhaseStats {
                name: "cluster_traced",
                units: tr.events,
                wall_secs: traced_secs,
                latency: percentiles(&[traced_secs * 1e3]),
            });
            (
                traced_eps,
                (events_per_sec - traced_eps) / events_per_sec.max(1e-9) * 100.0,
                tr.trace_events_written,
                tr.trace_events_dropped,
            )
        }
        None => (0.0, 0.0, 0, 0),
    };

    Ok(PerfOutcome {
        jobs,
        tenants,
        machines,
        shards: o.shards,
        seed,
        duration_secs,
        jobs_submitted: o.jobs_submitted,
        jobs_completed: o.jobs_completed,
        events: o.events,
        events_per_sec,
        traced_events_per_sec: traced_eps,
        trace_overhead_pct: overhead_pct,
        trace_events_written: tr_written,
        trace_events_dropped: tr_dropped,
        makespan_secs: o.makespan_secs,
        windows: o.windows,
        arrivals_fingerprint,
        counter_digest: fingerprint_digest(&o.fingerprint),
        counters: o.fingerprint,
        engine,
        phases,
        profile,
    })
}

/// Order-stable FNV-1a digest of a merged counter snapshot — the same
/// construction every sharded CLI driver prints, factored here so the
/// JSON record and the console agree.
pub fn fingerprint_digest(fp: &BTreeMap<String, u64>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for (k, v) in fp {
        for b in k.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h ^= *v;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------
// BENCH_perf.json (hand-rolled — no serde in the offline crate set)
// ---------------------------------------------------------------------

/// Render the outcome as the `BENCH_perf.json` record. The top-level
/// `events_per_sec` key is the gated figure and deliberately comes
/// first, so [`parse_events_per_sec`] reads it without a JSON parser.
pub fn render_json(o: &PerfOutcome) -> String {
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"perf\",\n");
    j.push_str(&format!("  \"events_per_sec\": {:.0},\n", o.events_per_sec));
    j.push_str(&format!("  \"jobs\": {},\n", o.jobs));
    j.push_str(&format!("  \"tenants\": {},\n", o.tenants));
    j.push_str(&format!("  \"machines\": {},\n", o.machines));
    j.push_str(&format!("  \"shards\": {},\n", o.shards));
    j.push_str(&format!("  \"seed\": {},\n", o.seed));
    j.push_str(&format!("  \"duration_secs\": {},\n", o.duration_secs));
    j.push_str(&format!("  \"jobs_submitted\": {},\n", o.jobs_submitted));
    j.push_str(&format!("  \"jobs_completed\": {},\n", o.jobs_completed));
    j.push_str(&format!("  \"events\": {},\n", o.events));
    j.push_str(&format!(
        "  \"traced_events_per_sec\": {:.0},\n",
        o.traced_events_per_sec
    ));
    j.push_str(&format!("  \"trace_overhead_pct\": {:.2},\n", o.trace_overhead_pct));
    j.push_str(&format!("  \"trace_events_written\": {},\n", o.trace_events_written));
    j.push_str(&format!("  \"trace_events_dropped\": {},\n", o.trace_events_dropped));
    j.push_str(&format!("  \"windows\": {},\n", o.windows));
    j.push_str(&format!("  \"makespan_secs\": {:.1},\n", o.makespan_secs));
    j.push_str(&format!(
        "  \"arrivals_fingerprint\": \"{:016x}\",\n",
        o.arrivals_fingerprint
    ));
    j.push_str(&format!("  \"counter_digest\": \"{:016x}\",\n", o.counter_digest));
    j.push_str("  \"engine\": {\n");
    j.push_str(&format!("    \"events\": {},\n", o.engine.events));
    j.push_str(&format!(
        "    \"calendar_events_per_sec\": {:.0},\n",
        o.engine.calendar_events_per_sec
    ));
    j.push_str(&format!(
        "    \"heap_events_per_sec\": {:.0},\n",
        o.engine.heap_events_per_sec
    ));
    j.push_str(&format!("    \"speedup\": {:.3}\n", o.engine.speedup));
    j.push_str("  },\n");
    j.push_str("  \"phases\": [\n");
    for (i, p) in o.phases.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"phase\": \"{}\", \"units\": {}, \"wall_secs\": {:.4}, \
             \"p50_ms\": {:.3}, \"p90_ms\": {:.3}, \"p99_ms\": {:.3}, \"max_ms\": {:.3}}}{}\n",
            p.name,
            p.units,
            p.wall_secs,
            p.latency.p50_ms,
            p.latency.p90_ms,
            p.latency.p99_ms,
            p.latency.max_ms,
            if i + 1 < o.phases.len() { "," } else { "" }
        ));
    }
    j.push_str("  ],\n");
    j.push_str("  \"profile\": [\n");
    for (i, p) in o.profile.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"phase\": \"{}\", \"count\": {}, \"total_secs\": {:.4}, \
             \"mean_us\": {:.3}, \"p50_us\": {:.3}, \"p99_us\": {:.3}, \"max_us\": {:.3}}}{}\n",
            p.phase,
            p.count,
            p.total_secs,
            p.mean_us,
            p.p50_us,
            p.p99_us,
            p.max_us,
            if i + 1 < o.profile.len() { "," } else { "" }
        ));
    }
    j.push_str("  ]\n}\n");
    j
}

/// Pull the top-level `events_per_sec` out of a `BENCH_perf.json`
/// (current or baseline). Key-prefix scan, not a JSON parser: the
/// renderer guarantees the key is top-level and first, and nested keys
/// like `calendar_events_per_sec` cannot match the quoted pattern.
pub fn parse_events_per_sec(json: &str) -> Option<f64> {
    let key = "\"events_per_sec\":";
    let at = json.find(key)?;
    let rest = json[at + key.len()..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_degenerate_and_ranked() {
        let p = percentiles(&[]);
        assert_eq!(p.p99_ms, 0.0);
        let p = percentiles(&[7.0]);
        assert_eq!((p.p50_ms, p.p99_ms, p.max_ms), (7.0, 7.0, 7.0));
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = percentiles(&samples);
        assert_eq!(p.p50_ms, 50.0);
        assert_eq!(p.p90_ms, 90.0);
        assert_eq!(p.p99_ms, 98.0);
        assert_eq!(p.max_ms, 100.0);
    }

    /// The two engines must fire identical event counts on the shared
    /// seeded schedule — the microbench's own sanity check, at a size
    /// small enough for the unit suite.
    #[test]
    fn engine_microbench_rounds_agree() {
        for seed in [1u64, 42, 0xDEAD_BEEF] {
            let (cf, _) = run_calendar_round(seed);
            let (hf, _) = run_heap_round(seed);
            assert_eq!(cf, hf, "seed {seed}");
            assert_eq!(cf, (ENGINE_WALKERS as u64) * (ENGINE_HOPS as u64 + 1));
        }
    }

    #[test]
    fn hop_delay_spans_all_calendar_paths() {
        let (mut short, mut mid, mut far) = (0u32, 0u32, 0u32);
        let mut rng = 99u64;
        for _ in 0..4096 {
            rng = lcg(rng);
            let d = hop_delay(rng);
            if d < SimTime::from_secs(1) {
                short += 1;
            } else if d < SimTime::from_secs(120) {
                mid += 1;
            } else {
                far += 1;
            }
        }
        assert!(short > 2000, "sub-second draws dominate: {short}");
        assert!(mid > 300, "ring-range draws present: {mid}");
        assert!(far > 50, "overflow-range draws present: {far}");
    }

    #[test]
    fn json_roundtrips_the_gated_figure() {
        let o = PerfOutcome {
            jobs: 10,
            tenants: 2,
            machines: 4,
            shards: 1,
            seed: 7,
            duration_secs: 60,
            jobs_submitted: 10,
            jobs_completed: 10,
            events: 1234,
            events_per_sec: 56789.0,
            traced_events_per_sec: 54321.0,
            trace_overhead_pct: 4.35,
            trace_events_written: 99,
            trace_events_dropped: 0,
            makespan_secs: 61.5,
            windows: 70,
            arrivals_fingerprint: 0xABCD,
            counter_digest: 0x1234,
            counters: BTreeMap::new(),
            engine: EngineBench {
                events: 100,
                calendar_events_per_sec: 2e6,
                heap_events_per_sec: 1e6,
                speedup: 2.0,
            },
            phases: vec![PhaseStats {
                name: "arrivals",
                units: 10,
                wall_secs: 0.01,
                latency: percentiles(&[1.0, 2.0]),
            }],
            profile: Vec::new(),
        };
        let json = render_json(&o);
        assert_eq!(parse_events_per_sec(&json), Some(56789.0));
        // the nested engine figures must not shadow the gated key, and
        // neither may the traced-rerun keys (none contains the quoted
        // `"events_per_sec"` pattern)
        assert!(json.find("\"events_per_sec\"").unwrap() < json.find("calendar_events_per_sec").unwrap());
        assert!(json.find("\"events_per_sec\"").unwrap() < json.find("traced_events_per_sec").unwrap());
        assert!(json.contains("\"trace_overhead_pct\": 4.35"));
        assert!(json.contains("\"trace_events_written\": 99"));
    }

    /// With a trace path set, the harness reruns the cluster phase
    /// traced: the overhead figures fill in, the trace file matches the
    /// written count line for line, and the rerun's counter fingerprint
    /// byte-matches the untraced run (run_perf_trace errors otherwise).
    #[test]
    fn traced_perf_rerun_records_overhead() {
        let mut spec = perf_spec(ClusterSpec::paper_testbed(), 4, 13);
        let path = std::env::temp_dir().join("vhpc_perf_trace_unit.jsonl");
        spec.trace_path = Some(path.to_string_lossy().into_owned());
        let o = run_perf_trace(spec, 40, 8, 2, 13, 120).expect("traced perf trace");
        assert!(o.traced_events_per_sec > 0.0);
        assert!(o.trace_events_written > 0, "traced rerun wrote no events");
        assert_eq!(o.trace_events_dropped, 0);
        assert!(o.phases.iter().any(|p| p.name == "cluster_traced"));
        let text = std::fs::read_to_string(&path).expect("trace file");
        assert_eq!(text.lines().count() as u64, o.trace_events_written);
        let _ = std::fs::remove_file(&path);
    }

    /// End-to-end smoke at unit-test scale: the harness runs, the
    /// stream fingerprint matches between synthesis and the cluster
    /// run, and the JSON renders with all four phases.
    #[test]
    fn tiny_perf_trace_runs_and_renders() {
        let spec = perf_spec(ClusterSpec::paper_testbed(), 4, 11);
        let o = run_perf_trace(spec, 40, 8, 1, 11, 120).expect("perf trace");
        // the open-loop stream targets ~40 arrivals over the horizon;
        // the exact count is whatever the seeded generator emits
        assert!(
            o.jobs_submitted > 0 && o.jobs_submitted < 400,
            "stream size near the target: {}",
            o.jobs_submitted
        );
        assert!(o.jobs_completed > 0);
        assert!(o.events > 0);
        assert!(o.events_per_sec > 0.0);
        assert_eq!(o.phases.len(), 4);
        // the scoped timers in the shard/scheduler paths ran under the
        // harness's profiling session: the breakdown must not be empty
        assert!(!o.profile.is_empty(), "per-phase profile missing");
        assert!(o.profile.iter().any(|p| p.phase == "window_merge"));
        let json = render_json(&o);
        assert_eq!(parse_events_per_sec(&json), Some(o.events_per_sec.round()));
        assert!(json.contains("\"profile\": ["));
    }
}
