//! Head-node state: the job queue, the slot-aware concurrent scheduler
//! and the consul-template hostfile watcher (the paper's Fig. 5 loop
//! lives here).
//!
//! Scheduling model: the hostfile advertises `slots` per compute node.
//! Each running job holds a *reservation* — a slice of specific host
//! slots carved out of the current hostfile — so any number of jobs can
//! run concurrently without two jobs ever sharing an advertised slot.
//! Dispatch is FIFO with **conservative backfill**: a younger job may
//! start ahead of the head-of-queue job only if (a) it fits in the
//! currently free slots the head job cannot use yet and (b) the slots
//! held by all younger jobs combined still leave the head job's full
//! width available once its elders drain. Invariant (b) is what makes
//! the backfill starvation-free: as long as running jobs terminate and
//! advertised capacity reaches the head job's width, the head job
//! eventually starts.

use crate::consul::template::{Template, TemplateWatcher};
use crate::mpi::hostfile::{HostSlot, Hostfile};
use crate::sim::SimTime;
use crate::util::ids::JobId;
use crate::vnet::addr::Ipv4;
use std::collections::{HashMap, HashSet, VecDeque};

/// What kind of work a job is.
#[derive(Debug, Clone)]
pub enum JobKind {
    /// Real distributed Jacobi solve (PJRT compute on rank threads).
    Jacobi { px: usize, py: usize, tile: usize, steps: usize },
    /// Synthetic job with a fixed virtual duration (for control-plane
    /// benches where real compute would only add noise).
    Synthetic { duration: SimTime },
}

/// A submitted job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    pub ranks: u32,
    pub kind: JobKind,
}

/// Lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Queued,
    Running { started: SimTime },
    Done { started: SimTime, finished: SimTime },
    Failed { reason: String },
}

/// Per-job record (running or completed).
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub spec: JobSpec,
    pub state: JobState,
    /// For Jacobi jobs: (steps, final residual).
    pub result: Option<(usize, f32)>,
    pub queued_at: SimTime,
}

/// A job the scheduler just dispatched: its spec plus the hostfile slice
/// reserved for it (what `mpirun --hostfile` gets for this job).
#[derive(Debug, Clone)]
pub struct StartedJob {
    pub spec: JobSpec,
    pub queued_at: SimTime,
    pub hostfile_slice: Hostfile,
    /// True when the job overtook the head-of-queue job via backfill.
    pub backfilled: bool,
}

/// The head container's state.
pub struct Head {
    pub watcher: TemplateWatcher,
    pub hostfile_text: String,
    /// When the hostfile last changed.
    pub hostfile_updated_at: SimTime,
    pub hostfile_renders: u64,
    pub queue: VecDeque<(JobSpec, SimTime)>,
    /// Concurrently running jobs, keyed by id.
    pub running: HashMap<JobId, JobRecord>,
    /// Per-job slot reservations (slices of the advertised hostfile).
    reserved: HashMap<JobId, Vec<HostSlot>>,
    pub completed: Vec<JobRecord>,
    pub poll_interval: SimTime,
    /// Cap on concurrent jobs (`usize::MAX` = slot-limited only). Set to
    /// 1 to reproduce the old one-job-at-a-time head for comparisons.
    pub max_concurrent: usize,
}

impl Default for Head {
    fn default() -> Self {
        Self::new()
    }
}

impl Head {
    pub fn new() -> Self {
        Self {
            watcher: TemplateWatcher::new(Template::mpi_hostfile()),
            hostfile_text: String::new(),
            hostfile_updated_at: SimTime::ZERO,
            hostfile_renders: 0,
            queue: VecDeque::new(),
            running: HashMap::new(),
            reserved: HashMap::new(),
            completed: Vec::new(),
            poll_interval: SimTime::from_millis(200),
            max_concurrent: usize::MAX,
        }
    }

    /// Parse the current hostfile (None when empty/invalid).
    pub fn hostfile(&self) -> Option<Hostfile> {
        Hostfile::parse(&self.hostfile_text).ok()
    }

    /// Total MPI slots currently advertised.
    pub fn slots_available(&self) -> u32 {
        self.hostfile().map(|h| h.total_slots()).unwrap_or(0)
    }

    /// Slots held by running jobs' reservations.
    pub fn reserved_slots(&self) -> u32 {
        self.running.values().map(|r| r.spec.ranks).sum()
    }

    /// Slots demanded by jobs still waiting in the queue.
    pub fn queued_slots(&self) -> u32 {
        self.queue.iter().map(|(j, _)| j.ranks).sum()
    }

    /// Slots demanded by queued + running jobs.
    pub fn demanded_slots(&self) -> u32 {
        self.queued_slots() + self.reserved_slots()
    }

    /// Advertised slots not reserved by any running job.
    pub fn free_slots(&self) -> u32 {
        self.free_per_host().iter().map(|h| h.slots).sum()
    }

    /// Per-host free capacity: advertised slots minus reservations, in
    /// hostfile order. Hosts that left the hostfile contribute nothing;
    /// reservations pointing at them are simply unmatched.
    fn free_per_host(&self) -> Vec<HostSlot> {
        let hf = match self.hostfile() {
            Some(hf) => hf,
            None => return Vec::new(),
        };
        let held = self.reserved_per_host();
        hf.hosts
            .into_iter()
            .map(|h| HostSlot {
                addr: h.addr,
                slots: h.slots.saturating_sub(held.get(&h.addr).copied().unwrap_or(0)),
            })
            .collect()
    }

    /// Reserved slot count per host address (for overbooking checks).
    pub fn reserved_per_host(&self) -> HashMap<Ipv4, u32> {
        let mut held: HashMap<Ipv4, u32> = HashMap::new();
        for slice in self.reserved.values() {
            for h in slice {
                *held.entry(h.addr).or_insert(0) += h.slots;
            }
        }
        held
    }

    /// Host addresses with at least one reserved slot (nodes the cluster
    /// must not retire while jobs hold them).
    pub fn reserved_addrs(&self) -> HashSet<Ipv4> {
        self.reserved
            .values()
            .flat_map(|slice| slice.iter().map(|h| h.addr))
            .collect()
    }

    /// Hosts where reservations exceed the advertised slot count. Always
    /// empty unless a reserved host shrank or left the hostfile.
    pub fn overbooked_hosts(&self) -> Vec<Ipv4> {
        let advertised: HashMap<Ipv4, u32> = self
            .hostfile()
            .map(|hf| hf.hosts.into_iter().map(|h| (h.addr, h.slots)).collect())
            .unwrap_or_default();
        self.reserved_per_host()
            .into_iter()
            .filter(|(addr, held)| *held > advertised.get(addr).copied().unwrap_or(0))
            .map(|(addr, _)| addr)
            .collect()
    }

    pub fn submit(&mut self, spec: JobSpec, now: SimTime) {
        self.queue.push_back((spec, now));
    }

    /// Dispatch the next startable job, reserving its slots: FIFO first,
    /// then conservative backfill. Call in a loop until `None` — each
    /// call starts at most one job. The returned record is already in
    /// `running`.
    pub fn start_next(&mut self, now: SimTime) -> Option<StartedJob> {
        if self.running.len() >= self.max_concurrent {
            return None;
        }
        // one hostfile parse per dispatch attempt: derive the total and
        // the per-host free pool from the same parsed view
        let hf = self.hostfile()?;
        let total = hf.total_slots();
        let held = self.reserved_per_host();
        let mut free: Vec<HostSlot> = hf
            .hosts
            .into_iter()
            .map(|h| HostSlot {
                addr: h.addr,
                slots: h.slots.saturating_sub(held.get(&h.addr).copied().unwrap_or(0)),
            })
            .collect();
        let free_total: u32 = free.iter().map(|h| h.slots).sum();
        let (head_id, head_ranks) = {
            let (head, _) = self.queue.front()?;
            (head.id, head.ranks)
        };
        let (idx, backfilled) = if head_ranks <= free_total {
            (0, false)
        } else {
            // Head blocked: backfill a younger job, but never let younger
            // jobs collectively hold more than `total - head_ranks` slots
            // (the head job keeps a claim on its full width).
            let younger_held: u32 = self
                .running
                .values()
                .filter(|r| r.spec.id > head_id)
                .map(|r| r.spec.ranks)
                .sum();
            let idx = self
                .queue
                .iter()
                .enumerate()
                .skip(1)
                .find(|(_, (j, _))| {
                    j.ranks <= free_total
                        && head_ranks
                            .checked_add(younger_held)
                            .and_then(|s| s.checked_add(j.ranks))
                            .map(|s| s <= total)
                            .unwrap_or(false)
                })
                .map(|(i, _)| i)?;
            (idx, true)
        };
        let (spec, queued_at) = self.queue.remove(idx).expect("index in range");
        let slice = carve(&mut free, spec.ranks).expect("fit checked above");
        self.reserved.insert(spec.id, slice.clone());
        self.running.insert(
            spec.id,
            JobRecord {
                spec: spec.clone(),
                state: JobState::Running { started: now },
                result: None,
                queued_at,
            },
        );
        Some(StartedJob { spec, queued_at, hostfile_slice: Hostfile { hosts: slice }, backfilled })
    }

    /// Remove a job from the running pool, releasing its reservation.
    pub fn finish(&mut self, id: JobId) -> Option<JobRecord> {
        self.reserved.remove(&id);
        self.running.remove(&id)
    }

    /// Fail a running job: release its slots and record the reason.
    pub fn fail(&mut self, id: JobId, reason: String) {
        if let Some(mut rec) = self.finish(id) {
            rec.state = JobState::Failed { reason };
            self.completed.push(rec);
        }
    }
}

/// Take `ranks` slots out of `free` (mutating it), filling hosts in
/// hostfile order. `None` if the free pool is too small.
fn carve(free: &mut [HostSlot], ranks: u32) -> Option<Vec<HostSlot>> {
    let total: u32 = free.iter().map(|h| h.slots).sum();
    if total < ranks {
        return None;
    }
    let mut need = ranks;
    let mut take = Vec::new();
    for h in free.iter_mut() {
        if need == 0 {
            break;
        }
        let t = h.slots.min(need);
        if t > 0 {
            take.push(HostSlot { addr: h.addr, slots: t });
            h.slots -= t;
            need -= t;
        }
    }
    Some(take)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn job(id: u32, ranks: u32) -> JobSpec {
        JobSpec {
            id: JobId::new(id),
            name: format!("job{id}"),
            ranks,
            kind: JobKind::Synthetic { duration: SimTime::from_secs(10) },
        }
    }

    #[test]
    fn jobs_wait_for_slots() {
        let mut h = Head::new();
        h.submit(job(0, 16), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_none(), "no hostfile yet");
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        let r = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(r.spec.id, JobId::new(0));
        assert_eq!(r.hostfile_slice.total_slots(), 16);
        assert!(matches!(h.running[&r.spec.id].state, JobState::Running { .. }));
    }

    #[test]
    fn concurrent_jobs_share_the_cluster() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 4), SimTime::ZERO);
        h.submit(job(1, 4), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert_eq!(h.running.len(), 2);
        assert_eq!(h.free_slots(), 16);
        assert!(h.overbooked_hosts().is_empty());
    }

    #[test]
    fn max_concurrent_one_reproduces_serial_head() {
        let mut h = Head::new();
        h.max_concurrent = 1;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 4), SimTime::ZERO);
        h.submit(job(1, 4), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert!(h.start_next(SimTime::ZERO).is_none(), "capped at one job");
        h.finish(JobId::new(0));
        assert!(h.start_next(SimTime::ZERO).is_some());
    }

    #[test]
    fn demanded_slots_counts_queue_and_running() {
        let mut h = Head::new();
        h.submit(job(0, 16), SimTime::ZERO);
        h.submit(job(1, 8), SimTime::ZERO);
        assert_eq!(h.demanded_slots(), 24);
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(h.queued_slots(), 8);
        assert_eq!(h.reserved_slots(), 16);
        assert_eq!(h.demanded_slots(), 24);
    }

    /// The seed's `fifo_order_holds` documented head-of-line blocking: a
    /// 1-rank job stuck behind a full-width job. Now the wide job takes
    /// the whole cluster and the narrow one waits only because zero
    /// slots are free — not because of the queue position.
    #[test]
    fn full_width_job_still_blocks_when_no_slots_free() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=32\n".into();
        h.submit(job(0, 32), SimTime::ZERO);
        h.submit(job(1, 1), SimTime::ZERO);
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(0));
        assert!(h.start_next(SimTime::ZERO).is_none(), "no free slots");
        h.finish(JobId::new(0));
        assert_eq!(h.start_next(SimTime::ZERO).unwrap().spec.id, JobId::new(1));
    }

    /// Backfill regression test (was `fifo_order_holds`, which asserted
    /// the bug): a narrow job overtakes a blocked wide job when it fits
    /// into slots the wide job cannot use yet.
    #[test]
    fn backfill_fills_spare_slots_behind_blocked_head() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=16\n10.10.0.3 slots=16\n".into();
        h.submit(job(0, 24), SimTime::ZERO);
        h.submit(job(1, 16), SimTime::ZERO); // head once job0 runs; blocked (8 free)
        h.submit(job(2, 4), SimTime::ZERO); // backfills into the 8 free slots
        let r0 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r0.spec.id, JobId::new(0));
        assert!(!r0.backfilled);
        let r2 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r2.spec.id, JobId::new(2), "narrow job must backfill");
        assert!(r2.backfilled);
        // 4 slots free, head needs 16: nothing else starts
        assert!(h.start_next(SimTime::ZERO).is_none());
        assert_eq!(h.queue.len(), 1);
        assert!(h.overbooked_hosts().is_empty());
    }

    /// Conservative guard: younger jobs may never hold so many slots
    /// that the head-of-queue job's full width cannot be assembled.
    #[test]
    fn backfill_never_overcommits_the_heads_claim() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=16\n10.10.0.3 slots=16\n".into();
        h.submit(job(0, 20), SimTime::ZERO);
        let _ = h.start_next(SimTime::ZERO).unwrap(); // 12 free
        h.submit(job(1, 24), SimTime::ZERO); // head, blocked
        h.submit(job(2, 10), SimTime::ZERO); // fits in 12 free, but 24+10 > 32
        assert!(
            h.start_next(SimTime::ZERO).is_none(),
            "backfill must leave the head job's width claimable"
        );
        h.submit(job(3, 8), SimTime::ZERO); // 24 + 8 <= 32: allowed
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(3));
        assert!(r.backfilled);
    }

    #[test]
    fn reservations_release_on_finish_and_fail() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(job(0, 8), SimTime::ZERO);
        h.submit(job(1, 8), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(h.free_slots(), 4);
        h.fail(JobId::new(0), "boom".into());
        assert_eq!(h.free_slots(), 12);
        assert!(matches!(h.completed[0].state, JobState::Failed { .. }));
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(1));
        h.finish(JobId::new(1));
        assert_eq!(h.free_slots(), 12);
        assert!(h.reserved_addrs().is_empty());
    }

    /// Property: over random job mixes, (a) no host is ever overbooked,
    /// (b) the queue fully drains (backfill never starves the head), and
    /// (c) every dispatched slice has exactly the job's width.
    #[test]
    fn prop_backfill_is_starvation_free_and_never_double_books() {
        let mut rng = Rng::new(2026);
        for trial in 0..40 {
            let mut h = Head::new();
            // 4 hosts x 12 slots = 48; every job individually fits
            h.hostfile_text =
                "10.0.0.1 slots=12\n10.0.0.2 slots=12\n10.0.0.3 slots=12\n10.0.0.4 slots=12\n"
                    .to_string();
            let total = h.slots_available();
            let n_jobs = 5 + rng.gen_range(15) as u32;
            for i in 0..n_jobs {
                let ranks = 1 + rng.gen_range(total as u64) as u32;
                h.submit(job(i, ranks), SimTime::ZERO);
            }
            let mut started = 0u32;
            let mut steps = 0u32;
            while started < n_jobs {
                steps += 1;
                assert!(steps < 10 * n_jobs + 100, "trial {trial}: scheduler wedged");
                while let Some(s) = h.start_next(SimTime::from_secs(steps as u64)) {
                    assert_eq!(s.hostfile_slice.total_slots(), s.spec.ranks, "trial {trial}");
                    started += 1;
                }
                assert!(h.overbooked_hosts().is_empty(), "trial {trial}: double-booked");
                // complete one random running job so slots churn
                let ids: Vec<JobId> = h.running.keys().copied().collect();
                if let Some(id) = rng.choose(&ids) {
                    h.finish(*id);
                }
            }
            assert!(h.queue.is_empty(), "trial {trial}: queue never drained");
        }
    }
}
