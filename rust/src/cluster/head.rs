//! Head-node state: the job queue, the slot-aware concurrent scheduler
//! and the consul-template hostfile watcher (the paper's Fig. 5 loop
//! lives here).
//!
//! Scheduling model: the hostfile advertises `slots` per compute node.
//! Each running job holds a *reservation* — a slice of specific host
//! slots carved out of the current hostfile — so any number of jobs can
//! run concurrently without two jobs ever sharing an advertised slot.
//! *Which* queued job is dispatched next, and whether a blocked
//! high-priority job may preempt running work, is delegated to the
//! head's [`SchedulePolicy`](crate::cluster::policy::SchedulePolicy):
//! FIFO + conservative backfill (the default, starvation-free without
//! runtime knowledge), EASY backfill (reservation-based, using the
//! jobs' known or estimated runtimes), or priority order with optional
//! preemption. Reservation *placement* is hostfile-order by default or
//! rack-packing when the policy is topology-aware.
//!
//! Two per-job counters are deliberately distinct: the **attempt
//! generation** advances on every early exit from the running pool
//! (fault requeue or preemption) and guards stale completion events,
//! while the **fault retry budget** is charged only when a node loss
//! kills the job — being preempted is the scheduler's choice and must
//! not count against the job.

use crate::cluster::policy::{Decision, PolicyKind, SchedulePolicy};
use crate::consul::template::{Template, TemplateWatcher};
use crate::mpi::hostfile::{HostSlot, Hostfile};
use crate::sim::SimTime;
use crate::util::ids::JobId;
use crate::vnet::addr::Ipv4;
use std::collections::{HashMap, HashSet, VecDeque};

/// What kind of work a job is.
#[derive(Debug, Clone)]
pub enum JobKind {
    /// Real distributed Jacobi solve (PJRT compute on rank threads).
    Jacobi { px: usize, py: usize, tile: usize, steps: usize },
    /// Synthetic job with a fixed virtual duration (for control-plane
    /// benches where real compute would only add noise).
    Synthetic { duration: SimTime },
}

/// Jacobi's residual-check cadence doubles as its restart checkpoint:
/// a job requeued after losing a node resumes from the last completed
/// multiple of this many steps (work past the checkpoint is redone).
pub const JACOBI_CHECKPOINT_STEPS: usize = 20;

/// A submitted job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    pub ranks: u32,
    pub kind: JobKind,
    /// Scheduling priority: higher runs sooner under the priority
    /// policy; 0 is normal batch work. Ignored by FIFO/EASY dispatch
    /// order but always feeds the autoscaler's weighted demand signal.
    pub priority: i32,
}

impl JobSpec {
    /// Planning estimate of the job's virtual runtime, used by EASY
    /// backfill to compute the blocked head job's reservation.
    /// Synthetic durations are known exactly (for a requeued job the
    /// stored duration is already the remaining work); Jacobi uses a
    /// coarse per-step cost model scaled by the tile area.
    pub fn estimated_duration(&self) -> SimTime {
        match &self.kind {
            JobKind::Synthetic { duration } => *duration,
            JobKind::Jacobi { tile, steps, .. } => {
                let per_step_ms = ((tile * tile) as u64 / 1024).max(1);
                SimTime::from_millis(per_step_ms * (*steps).max(1) as u64)
            }
        }
    }
}

/// Lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Queued,
    Running { started: SimTime },
    Done { started: SimTime, finished: SimTime },
    Failed { reason: String },
}

/// Per-job record (running or completed).
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub spec: JobSpec,
    pub state: JobState,
    /// For Jacobi jobs: (steps, final residual).
    pub result: Option<(usize, f32)>,
    pub queued_at: SimTime,
    /// How many times this job has already been requeued after losing a
    /// node (0 = first run).
    pub attempt: u32,
    /// Virtual duration the dispatcher scheduled for this attempt (set
    /// at launch; used to prorate progress credit when the job is lost).
    pub planned_duration: Option<SimTime>,
}

impl JobRecord {
    /// When the dispatcher expects this job's slots back: its start
    /// plus the planned duration (or the spec's estimate before the
    /// launch pins one), clamped to `now` for overdue jobs. This is
    /// the signal EASY backfill builds the head job's reservation
    /// from — a job that dies takes its prediction with it, because
    /// the policy recomputes from the live running pool every time.
    pub fn predicted_finish(&self, now: SimTime) -> SimTime {
        let started = match self.state {
            JobState::Running { started } => started,
            _ => now,
        };
        let dur = self
            .planned_duration
            .unwrap_or_else(|| self.spec.estimated_duration());
        (started + dur).max(now)
    }
}

/// A job the scheduler just dispatched: its spec plus the hostfile slice
/// reserved for it (what `mpirun --hostfile` gets for this job).
#[derive(Debug, Clone)]
pub struct StartedJob {
    pub spec: JobSpec,
    pub queued_at: SimTime,
    pub hostfile_slice: Hostfile,
    /// True when the job overtook the head-of-queue job via backfill.
    pub backfilled: bool,
    /// Which attempt this dispatch is (guards completion events from
    /// earlier attempts of the same job).
    pub attempt: u32,
    /// Jobs checkpointed-and-requeued to make room for this one
    /// (non-empty only under the priority policy with preemption).
    pub preempted: Vec<JobId>,
    /// Virtual work the preempted jobs' reruns must redo (their
    /// progress past the last checkpoint).
    pub preempt_wasted: SimTime,
}

/// What the head did with a running job whose reservation lost a node.
#[derive(Debug, Clone, PartialEq)]
pub enum LossOutcome {
    /// Requeued at the head of the queue with partial-progress credit.
    /// `wasted` is the virtual work the rerun must redo (credit gap).
    Requeued { id: JobId, attempt: u32, wasted: SimTime },
    /// Retry budget exhausted: recorded as permanently failed.
    Abandoned { id: JobId },
    /// The job was not in the running pool (already finished or reaped).
    NotRunning,
}

/// The head container's state.
pub struct Head {
    pub watcher: TemplateWatcher,
    pub hostfile_text: String,
    /// When the hostfile last changed.
    pub hostfile_updated_at: SimTime,
    pub hostfile_renders: u64,
    pub queue: VecDeque<(JobSpec, SimTime)>,
    /// Concurrently running jobs, keyed by id.
    pub running: HashMap<JobId, JobRecord>,
    /// Per-job slot reservations (slices of the advertised hostfile).
    reserved: HashMap<JobId, Vec<HostSlot>>,
    pub completed: Vec<JobRecord>,
    pub poll_interval: SimTime,
    /// Cap on concurrent jobs (`usize::MAX` = slot-limited only). Set to
    /// 1 to reproduce the old one-job-at-a-time head for comparisons.
    pub max_concurrent: usize,
    /// How many times a job may be requeued after losing a node before
    /// it is recorded as permanently failed.
    pub max_retries: u32,
    /// Dispatch-order + placement policy (see
    /// [`SchedulePolicy`](crate::cluster::policy::SchedulePolicy));
    /// the default reproduces the pre-policy FIFO head exactly.
    pub policy: SchedulePolicy,
    /// Host address -> rack index, for topology-aware placement and
    /// the per-job rack-spread metric. Populated by the cluster as
    /// containers come up; unknown hosts share one pseudo-rack.
    pub rack_of: HashMap<Ipv4, usize>,
    /// Fault-retry budget consumed per job. Charged only by
    /// [`Head::handle_lost_job`]; entries cleared on completion.
    retries: HashMap<JobId, u32>,
    /// Attempt generation per job: advanced by *every* early exit from
    /// the running pool — fault requeue or preemption — so a stale
    /// completion event can never complete a newer attempt. Always
    /// >= the retry budget spent.
    attempts: HashMap<JobId, u32>,
    /// Jacobi steps credited from prior attempts (the resume point).
    jacobi_progress: HashMap<JobId, usize>,
    /// When each job first lost a node — MTTR is measured from here to
    /// the job's eventual completion. Cleared on completion/abandonment.
    pub first_failed_at: HashMap<JobId, SimTime>,
}

impl Default for Head {
    fn default() -> Self {
        Self::new()
    }
}

impl Head {
    pub fn new() -> Self {
        Self {
            watcher: TemplateWatcher::new(Template::mpi_hostfile()),
            hostfile_text: String::new(),
            hostfile_updated_at: SimTime::ZERO,
            hostfile_renders: 0,
            queue: VecDeque::new(),
            running: HashMap::new(),
            reserved: HashMap::new(),
            completed: Vec::new(),
            poll_interval: SimTime::from_millis(200),
            max_concurrent: usize::MAX,
            max_retries: 3,
            policy: SchedulePolicy::default(),
            rack_of: HashMap::new(),
            retries: HashMap::new(),
            attempts: HashMap::new(),
            jacobi_progress: HashMap::new(),
            first_failed_at: HashMap::new(),
        }
    }

    /// Parse the current hostfile (None when empty/invalid).
    pub fn hostfile(&self) -> Option<Hostfile> {
        Hostfile::parse(&self.hostfile_text).ok()
    }

    /// Total MPI slots currently advertised.
    pub fn slots_available(&self) -> u32 {
        self.hostfile().map(|h| h.total_slots()).unwrap_or(0)
    }

    /// Slots held by running jobs' reservations.
    pub fn reserved_slots(&self) -> u32 {
        self.running.values().map(|r| r.spec.ranks).sum()
    }

    /// Slots demanded by jobs still waiting in the queue.
    pub fn queued_slots(&self) -> u32 {
        self.queue.iter().map(|(j, _)| j.ranks).sum()
    }

    /// Slots demanded by queued + running jobs.
    pub fn demanded_slots(&self) -> u32 {
        self.queued_slots() + self.reserved_slots()
    }

    /// Advertised slots not reserved by any running job.
    pub fn free_slots(&self) -> u32 {
        self.free_per_host().iter().map(|h| h.slots).sum()
    }

    /// Per-host free capacity: advertised slots minus reservations, in
    /// hostfile order. Hosts that left the hostfile contribute nothing;
    /// reservations pointing at them are simply unmatched.
    fn free_per_host(&self) -> Vec<HostSlot> {
        let hf = match self.hostfile() {
            Some(hf) => hf,
            None => return Vec::new(),
        };
        let held = self.reserved_per_host();
        hf.hosts
            .into_iter()
            .map(|h| HostSlot {
                addr: h.addr,
                slots: h.slots.saturating_sub(held.get(&h.addr).copied().unwrap_or(0)),
            })
            .collect()
    }

    /// Reserved slot count per host address (for overbooking checks).
    pub fn reserved_per_host(&self) -> HashMap<Ipv4, u32> {
        let mut held: HashMap<Ipv4, u32> = HashMap::new();
        for slice in self.reserved.values() {
            for h in slice {
                *held.entry(h.addr).or_insert(0) += h.slots;
            }
        }
        held
    }

    /// Host addresses with at least one reserved slot (nodes the cluster
    /// must not retire while jobs hold them).
    pub fn reserved_addrs(&self) -> HashSet<Ipv4> {
        self.reserved
            .values()
            .flat_map(|slice| slice.iter().map(|h| h.addr))
            .collect()
    }

    /// Hosts where reservations exceed the advertised slot count. Always
    /// empty unless a reserved host shrank or left the hostfile.
    pub fn overbooked_hosts(&self) -> Vec<Ipv4> {
        let advertised: HashMap<Ipv4, u32> = self
            .hostfile()
            .map(|hf| hf.hosts.into_iter().map(|h| (h.addr, h.slots)).collect())
            .unwrap_or_default();
        self.reserved_per_host()
            .into_iter()
            .filter(|(addr, held)| *held > advertised.get(addr).copied().unwrap_or(0))
            .map(|(addr, _)| addr)
            .collect()
    }

    pub fn submit(&mut self, spec: JobSpec, now: SimTime) {
        self.queue.push_back((spec, now));
    }

    /// Dispatch the next startable job under the configured policy,
    /// reserving its slots. Call in a loop until `None` — each call
    /// starts at most one job (possibly preempting lower-priority
    /// running jobs first; see [`StartedJob::preempted`]). The
    /// returned record is already in `running`.
    pub fn start_next(&mut self, now: SimTime) -> Option<StartedJob> {
        let mut preempted: Vec<JobId> = Vec::new();
        let mut preempt_wasted = SimTime::ZERO;
        let may_preempt =
            self.policy.kind == PolicyKind::Priority && self.policy.preemption;
        loop {
            // At the concurrency cap nothing can *start*, but a
            // preempting policy may still swap running work (preempt +
            // start keeps the job count constant), so only short-circuit
            // when no preemption is possible.
            if self.running.len() >= self.max_concurrent && !may_preempt {
                return None;
            }
            // one hostfile parse per dispatch attempt: derive the total
            // and the per-host free pool from the same parsed view
            let hf = self.hostfile()?;
            let total = hf.total_slots();
            let held = self.reserved_per_host();
            let mut free: Vec<HostSlot> = hf
                .hosts
                .into_iter()
                .map(|h| HostSlot {
                    addr: h.addr,
                    slots: h.slots.saturating_sub(held.get(&h.addr).copied().unwrap_or(0)),
                })
                .collect();
            let free_total: u32 = free.iter().map(|h| h.slots).sum();
            if self.queue.is_empty() {
                return None;
            }
            let queue_view: Vec<crate::cluster::policy::QueuedJob> = self
                .queue
                .iter()
                .map(|(j, _)| crate::cluster::policy::QueuedJob {
                    id: j.id,
                    ranks: j.ranks,
                    priority: j.priority,
                    est: j.estimated_duration(),
                })
                .collect();
            // sorted by id so every policy sees a deterministic view of
            // the (hash-ordered) running pool
            let mut running_view: Vec<crate::cluster::policy::RunningJob> = self
                .running
                .values()
                .map(|r| crate::cluster::policy::RunningJob {
                    id: r.spec.id,
                    ranks: r.spec.ranks,
                    priority: r.spec.priority,
                    predicted_finish: r.predicted_finish(now),
                })
                .collect();
            running_view.sort_by_key(|r| r.id);
            match self.policy.decide(now, &queue_view, &running_view, free_total, total) {
                Decision::Wait => return None,
                Decision::Preempt { victim } => {
                    let (_, wasted) = self.preempt(victim, now)?;
                    preempted.push(victim);
                    preempt_wasted += wasted;
                    // re-decide against the post-preemption state
                }
                Decision::Start { idx, backfilled } => {
                    if self.running.len() >= self.max_concurrent {
                        return None;
                    }
                    let (spec, queued_at) = self.queue.remove(idx).expect("index in range");
                    let slice = if self.policy.topo_aware {
                        crate::cluster::policy::carve_topo(&mut free, spec.ranks, &self.rack_of)
                    } else {
                        carve(&mut free, spec.ranks)
                    }
                    .expect("fit checked by the policy");
                    let attempt = self.attempts.get(&spec.id).copied().unwrap_or(0);
                    self.reserved.insert(spec.id, slice.clone());
                    self.running.insert(
                        spec.id,
                        JobRecord {
                            spec: spec.clone(),
                            state: JobState::Running { started: now },
                            result: None,
                            queued_at,
                            attempt,
                            planned_duration: None,
                        },
                    );
                    return Some(StartedJob {
                        spec,
                        queued_at,
                        hostfile_slice: Hostfile { hosts: slice },
                        backfilled,
                        attempt,
                        preempted,
                        preempt_wasted,
                    });
                }
            }
        }
    }

    /// Remove a job from the running pool, releasing its reservation and
    /// folding progress credited from earlier attempts into its result.
    pub fn finish(&mut self, id: JobId) -> Option<JobRecord> {
        self.reserved.remove(&id);
        let mut rec = self.running.remove(&id)?;
        self.retries.remove(&id);
        self.attempts.remove(&id);
        if let Some(prior) = self.jacobi_progress.remove(&id) {
            if let Some((steps, residual)) = rec.result {
                rec.result = Some((steps + prior, residual));
            }
        }
        Some(rec)
    }

    /// Fail a running job: release its slots and record the reason.
    pub fn fail(&mut self, id: JobId, reason: String) {
        if let Some(mut rec) = self.finish(id) {
            self.first_failed_at.remove(&id);
            rec.state = JobState::Failed { reason };
            self.completed.push(rec);
        }
    }

    /// Running jobs whose reserved slice references a host that is no
    /// longer advertised by the (health-gated) hostfile — the recovery
    /// pipeline's per-tick cross-check. Sorted for determinism.
    pub fn lost_jobs(&self) -> Vec<JobId> {
        let advertised: HashSet<Ipv4> = self
            .hostfile()
            .map(|hf| hf.hosts.into_iter().map(|h| h.addr).collect())
            .unwrap_or_default();
        let mut ids: Vec<JobId> = self
            .reserved
            .iter()
            .filter(|(_, slice)| slice.iter().any(|h| !advertised.contains(&h.addr)))
            .map(|(&id, _)| id)
            .collect();
        ids.sort();
        ids
    }

    /// Running jobs holding slots on `addr` — for immediate failure when
    /// a machine dies under them (mpirun exits long before the TTL).
    pub fn jobs_on_addr(&self, addr: Ipv4) -> Vec<JobId> {
        let mut ids: Vec<JobId> = self
            .reserved
            .iter()
            .filter(|(_, slice)| slice.iter().any(|h| h.addr == addr))
            .map(|(&id, _)| id)
            .collect();
        ids.sort();
        ids
    }

    /// A dispatched job never actually launched (a host in its slice was
    /// already unreachable): put it back at the head of the queue without
    /// charging its retry budget — no work was started, the failure is
    /// the launcher's, not the job's.
    pub fn unlaunch(&mut self, id: JobId, now: SimTime) {
        if let Some(rec) = self.running.remove(&id) {
            self.reserved.remove(&id);
            self.first_failed_at.entry(id).or_insert(now);
            self.queue.push_front((rec.spec, rec.queued_at));
        }
    }

    /// Compute the rerun spec-kind plus the virtual work the rerun must
    /// redo when `rec` leaves the running pool early, crediting partial
    /// progress: synthetic jobs resume at their remaining duration
    /// (continuous checkpointing, zero waste), Jacobi restarts from the
    /// last completed residual checkpoint. Shared by the fault-requeue
    /// and preemption paths so the two can never drift.
    fn credited_rerun(&mut self, rec: &JobRecord, now: SimTime) -> (JobKind, SimTime) {
        let started = match rec.state {
            JobState::Running { started } => started,
            _ => now,
        };
        let elapsed = now.saturating_sub(started);
        match rec.spec.kind.clone() {
            JobKind::Synthetic { duration } => {
                // the elapsed virtual time is credited in full: the rerun
                // only owes the remainder
                let remaining = duration.saturating_sub(elapsed).max(SimTime::from_secs(1));
                (JobKind::Synthetic { duration: remaining }, SimTime::ZERO)
            }
            JobKind::Jacobi { px, py, tile, steps } => {
                // credit the steps executed this attempt, prorated by how
                // much of the planned virtual duration elapsed, rounded
                // down to the last completed checkpoint
                let ran = rec.result.map(|(s, _)| s).unwrap_or(0).min(steps);
                let frac = match rec.planned_duration {
                    Some(d) if d > SimTime::ZERO => {
                        (elapsed.as_secs_f64() / d.as_secs_f64()).min(1.0)
                    }
                    _ => 0.0,
                };
                let ckpt = JACOBI_CHECKPOINT_STEPS.min(steps.max(1)).max(1);
                // steps the job had virtually performed when it stopped
                let done_virtual = ((ran as f64 * frac) as usize).min(steps);
                let credited = (done_virtual / ckpt * ckpt).min(steps);
                *self.jacobi_progress.entry(rec.spec.id).or_insert(0) += credited;
                // work past the checkpoint is redone by the rerun
                let rerun_steps = done_virtual.saturating_sub(credited);
                let wasted = match rec.planned_duration {
                    Some(d) if ran > 0 => SimTime::from_secs_f64(
                        d.as_secs_f64() * rerun_steps as f64 / ran as f64,
                    ),
                    _ => SimTime::ZERO,
                };
                let remaining = (steps - credited).max(1);
                (JobKind::Jacobi { px, py, tile, steps: remaining }, wasted)
            }
        }
    }

    /// Advance a job's attempt generation (stale-completion guard).
    fn bump_attempt(&mut self, id: JobId) -> u32 {
        let a = self.attempts.entry(id).or_insert(0);
        *a += 1;
        *a
    }

    /// Checkpoint-and-requeue a running job to make room for
    /// higher-priority work. Shares the partial-progress credit path
    /// with [`Head::handle_lost_job`], but does **not** charge the
    /// fault retry budget — preemption is the scheduler's choice, not
    /// a node failure. The attempt generation still advances, so a
    /// completion event scheduled for the preempted run can never
    /// complete the requeued job early. Returns the new attempt
    /// generation and the virtual work the rerun must redo.
    pub fn preempt(&mut self, id: JobId, now: SimTime) -> Option<(u32, SimTime)> {
        let rec = self.running.remove(&id)?;
        self.reserved.remove(&id);
        let (kind, wasted) = self.credited_rerun(&rec, now);
        let attempt = self.bump_attempt(id);
        let spec = JobSpec { kind, ..rec.spec.clone() };
        self.queue.push_back((spec, rec.queued_at));
        Some((attempt, wasted))
    }

    /// A running job's reservation lost a node (machine death, hang or
    /// partition): release the slots and either requeue the job with
    /// partial-progress credit — synthetic jobs resume at their remaining
    /// duration, Jacobi restarts from the last completed checkpoint — or,
    /// once its retry budget is spent, record it as permanently failed.
    pub fn handle_lost_job(&mut self, id: JobId, now: SimTime, reason: &str) -> LossOutcome {
        if !self.running.contains_key(&id) {
            return LossOutcome::NotRunning;
        }
        let spent = self.retries.get(&id).copied().unwrap_or(0);
        if spent >= self.max_retries {
            // budget spent: the regular fail path already releases the
            // reservation, folds credited progress into the result and
            // records the job as permanently failed
            self.fail(
                id,
                format!("{reason} (retry budget of {} exhausted)", self.max_retries),
            );
            return LossOutcome::Abandoned { id };
        }
        let rec = match self.running.remove(&id) {
            Some(rec) => rec,
            None => return LossOutcome::NotRunning,
        };
        self.reserved.remove(&id);
        self.first_failed_at.entry(id).or_insert(now);
        let (kind, wasted) = self.credited_rerun(&rec, now);
        self.retries.insert(id, spent + 1);
        let attempt = self.bump_attempt(id);
        let spec = JobSpec { kind, ..rec.spec.clone() };
        self.queue.push_front((spec, rec.queued_at));
        LossOutcome::Requeued { id, attempt, wasted }
    }

    /// Priority-weighted queue demand for the autoscaler: each queued
    /// job contributes its width scaled by
    /// [`priority_weight`](crate::cluster::policy::priority_weight),
    /// so a backlog of urgent work provisions capacity harder than the
    /// same slot count of batch work. Equals [`Head::queued_slots`]
    /// when everything queued is priority 0.
    pub fn weighted_queued_slots(&self) -> u32 {
        self.queue
            .iter()
            .map(|(j, _)| {
                (j.ranks as f64 * crate::cluster::policy::priority_weight(j.priority)).ceil()
                    as u32
            })
            .sum()
    }
}

/// Take `ranks` slots out of `free` (mutating it), filling hosts in
/// hostfile order. `None` if the free pool is too small.
fn carve(free: &mut [HostSlot], ranks: u32) -> Option<Vec<HostSlot>> {
    let total: u32 = free.iter().map(|h| h.slots).sum();
    if total < ranks {
        return None;
    }
    let mut need = ranks;
    let mut take = Vec::new();
    for h in free.iter_mut() {
        if need == 0 {
            break;
        }
        let t = h.slots.min(need);
        if t > 0 {
            take.push(HostSlot { addr: h.addr, slots: t });
            h.slots -= t;
            need -= t;
        }
    }
    Some(take)
}

/// Width-only carve exposed for the policy module's width-vs-topology
/// comparison tests.
#[cfg(test)]
pub(crate) fn carve_for_test(free: &mut [HostSlot], ranks: u32) -> Option<Vec<HostSlot>> {
    carve(free, ranks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::policy::PolicyKind;
    use crate::util::Rng;

    fn job(id: u32, ranks: u32) -> JobSpec {
        jobd(id, ranks, 10)
    }

    fn jobd(id: u32, ranks: u32, secs: u64) -> JobSpec {
        JobSpec {
            id: JobId::new(id),
            name: format!("job{id}"),
            ranks,
            kind: JobKind::Synthetic { duration: SimTime::from_secs(secs) },
            priority: 0,
        }
    }

    fn jobp(id: u32, ranks: u32, secs: u64, priority: i32) -> JobSpec {
        JobSpec { priority, ..jobd(id, ranks, secs) }
    }

    #[test]
    fn jobs_wait_for_slots() {
        let mut h = Head::new();
        h.submit(job(0, 16), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_none(), "no hostfile yet");
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        let r = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(r.spec.id, JobId::new(0));
        assert_eq!(r.hostfile_slice.total_slots(), 16);
        assert!(matches!(h.running[&r.spec.id].state, JobState::Running { .. }));
    }

    #[test]
    fn concurrent_jobs_share_the_cluster() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 4), SimTime::ZERO);
        h.submit(job(1, 4), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert_eq!(h.running.len(), 2);
        assert_eq!(h.free_slots(), 16);
        assert!(h.overbooked_hosts().is_empty());
    }

    #[test]
    fn max_concurrent_one_reproduces_serial_head() {
        let mut h = Head::new();
        h.max_concurrent = 1;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 4), SimTime::ZERO);
        h.submit(job(1, 4), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert!(h.start_next(SimTime::ZERO).is_none(), "capped at one job");
        h.finish(JobId::new(0));
        assert!(h.start_next(SimTime::ZERO).is_some());
    }

    #[test]
    fn demanded_slots_counts_queue_and_running() {
        let mut h = Head::new();
        h.submit(job(0, 16), SimTime::ZERO);
        h.submit(job(1, 8), SimTime::ZERO);
        assert_eq!(h.demanded_slots(), 24);
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(h.queued_slots(), 8);
        assert_eq!(h.reserved_slots(), 16);
        assert_eq!(h.demanded_slots(), 24);
    }

    /// The seed's `fifo_order_holds` documented head-of-line blocking: a
    /// 1-rank job stuck behind a full-width job. Now the wide job takes
    /// the whole cluster and the narrow one waits only because zero
    /// slots are free — not because of the queue position.
    #[test]
    fn full_width_job_still_blocks_when_no_slots_free() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=32\n".into();
        h.submit(job(0, 32), SimTime::ZERO);
        h.submit(job(1, 1), SimTime::ZERO);
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(0));
        assert!(h.start_next(SimTime::ZERO).is_none(), "no free slots");
        h.finish(JobId::new(0));
        assert_eq!(h.start_next(SimTime::ZERO).unwrap().spec.id, JobId::new(1));
    }

    /// Backfill regression test (was `fifo_order_holds`, which asserted
    /// the bug): a narrow job overtakes a blocked wide job when it fits
    /// into slots the wide job cannot use yet.
    #[test]
    fn backfill_fills_spare_slots_behind_blocked_head() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=16\n10.10.0.3 slots=16\n".into();
        h.submit(job(0, 24), SimTime::ZERO);
        h.submit(job(1, 16), SimTime::ZERO); // head once job0 runs; blocked (8 free)
        h.submit(job(2, 4), SimTime::ZERO); // backfills into the 8 free slots
        let r0 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r0.spec.id, JobId::new(0));
        assert!(!r0.backfilled);
        let r2 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r2.spec.id, JobId::new(2), "narrow job must backfill");
        assert!(r2.backfilled);
        // 4 slots free, head needs 16: nothing else starts
        assert!(h.start_next(SimTime::ZERO).is_none());
        assert_eq!(h.queue.len(), 1);
        assert!(h.overbooked_hosts().is_empty());
    }

    /// Conservative guard: younger jobs may never hold so many slots
    /// that the head-of-queue job's full width cannot be assembled.
    #[test]
    fn backfill_never_overcommits_the_heads_claim() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=16\n10.10.0.3 slots=16\n".into();
        h.submit(job(0, 20), SimTime::ZERO);
        let _ = h.start_next(SimTime::ZERO).unwrap(); // 12 free
        h.submit(job(1, 24), SimTime::ZERO); // head, blocked
        h.submit(job(2, 10), SimTime::ZERO); // fits in 12 free, but 24+10 > 32
        assert!(
            h.start_next(SimTime::ZERO).is_none(),
            "backfill must leave the head job's width claimable"
        );
        h.submit(job(3, 8), SimTime::ZERO); // 24 + 8 <= 32: allowed
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(3));
        assert!(r.backfilled);
    }

    #[test]
    fn reservations_release_on_finish_and_fail() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(job(0, 8), SimTime::ZERO);
        h.submit(job(1, 8), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(h.free_slots(), 4);
        h.fail(JobId::new(0), "boom".into());
        assert_eq!(h.free_slots(), 12);
        assert!(matches!(h.completed[0].state, JobState::Failed { .. }));
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(1));
        h.finish(JobId::new(1));
        assert_eq!(h.free_slots(), 12);
        assert!(h.reserved_addrs().is_empty());
    }

    #[test]
    fn lost_job_requeues_with_remaining_duration() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        h.submit(job(0, 16), SimTime::ZERO);
        let started = h.start_next(SimTime::from_secs(10)).unwrap();
        assert_eq!(started.attempt, 0);
        // node 10.10.0.3 dies 4s into the 10s job
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(14), "node died");
        assert!(
            matches!(out, LossOutcome::Requeued { attempt: 1, .. }),
            "{out:?}"
        );
        assert!(h.running.is_empty());
        assert!(h.reserved_addrs().is_empty(), "slots must be released");
        assert_eq!(h.queue.len(), 1);
        let (spec, _) = h.queue.front().unwrap();
        match &spec.kind {
            JobKind::Synthetic { duration } => {
                assert_eq!(*duration, SimTime::from_secs(6), "elapsed time is credited");
            }
            other => panic!("kind changed: {other:?}"),
        }
        // the rerun carries the bumped attempt number
        let restarted = h.start_next(SimTime::from_secs(20)).unwrap();
        assert_eq!(restarted.attempt, 1);
        assert_eq!(h.first_failed_at[&JobId::new(0)], SimTime::from_secs(14));
    }

    #[test]
    fn retry_budget_exhaustion_abandons_the_job() {
        let mut h = Head::new();
        h.max_retries = 2;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 8), SimTime::ZERO);
        for round in 0..3 {
            let s = h.start_next(SimTime::from_secs(round)).unwrap();
            assert_eq!(s.attempt, round as u32);
            let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(round + 1), "boom");
            if round < 2 {
                assert!(matches!(out, LossOutcome::Requeued { .. }), "{out:?}");
            } else {
                assert_eq!(out, LossOutcome::Abandoned { id: JobId::new(0) });
            }
        }
        assert!(h.queue.is_empty());
        assert!(h.running.is_empty());
        assert_eq!(h.completed.len(), 1);
        assert!(matches!(h.completed[0].state, JobState::Failed { .. }));
        // a second report for the same job is a no-op
        assert_eq!(
            h.handle_lost_job(JobId::new(0), SimTime::from_secs(9), "boom"),
            LossOutcome::NotRunning
        );
    }

    #[test]
    fn jacobi_resumes_from_the_last_checkpoint() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(
            JobSpec {
                id: JobId::new(0),
                name: "jac".into(),
                ranks: 16,
                kind: JobKind::Jacobi { px: 4, py: 4, tile: 64, steps: 100 },
                priority: 0,
            },
            SimTime::ZERO,
        );
        h.start_next(SimTime::ZERO).unwrap();
        // the dispatcher ran all 100 steps and planned a 100s duration
        let rec = h.running.get_mut(&JobId::new(0)).unwrap();
        rec.result = Some((100, 0.5));
        rec.planned_duration = Some(SimTime::from_secs(100));
        // the node dies halfway through the virtual duration: 50 steps
        // performed -> rounds down to checkpoint 40
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(50), "died");
        let LossOutcome::Requeued { wasted, .. } = out else {
            panic!("{out:?}");
        };
        assert_eq!(wasted, SimTime::from_secs(10), "50 done - 40 credited = 10s redone");
        let (spec, _) = h.queue.front().unwrap();
        match &spec.kind {
            JobKind::Jacobi { steps, .. } => assert_eq!(*steps, 60, "resume at step 40"),
            other => panic!("kind changed: {other:?}"),
        }
        // on eventual completion the credited steps fold into the result
        h.start_next(SimTime::from_secs(60)).unwrap();
        h.running.get_mut(&JobId::new(0)).unwrap().result = Some((60, 1e-7));
        let done = h.finish(JobId::new(0)).unwrap();
        assert_eq!(done.result, Some((100, 1e-7)));
    }

    #[test]
    fn lost_jobs_cross_checks_reservations_against_the_hostfile() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        h.submit(job(0, 16), SimTime::ZERO); // spans both hosts
        h.submit(job(1, 4), SimTime::ZERO); // fits on the first host
        h.start_next(SimTime::ZERO).unwrap();
        h.start_next(SimTime::ZERO).unwrap();
        assert!(h.lost_jobs().is_empty());
        // the second host drops out of the hostfile (TTL expiry)
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        assert_eq!(h.lost_jobs(), vec![JobId::new(0)]);
        let addr = Ipv4::parse("10.10.0.3").unwrap();
        assert_eq!(h.jobs_on_addr(addr), vec![JobId::new(0)]);
        assert!(h.jobs_on_addr(Ipv4::parse("10.10.0.9").unwrap()).is_empty());
    }

    #[test]
    fn unlaunch_requeues_without_charging_the_budget() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(job(0, 8), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        h.unlaunch(JobId::new(0), SimTime::from_secs(1));
        assert!(h.running.is_empty());
        assert_eq!(h.queue.len(), 1);
        let s = h.start_next(SimTime::from_secs(2)).unwrap();
        assert_eq!(s.attempt, 0, "an aborted launch must not consume a retry");
    }

    /// Property: over random job mixes, (a) no host is ever overbooked,
    /// (b) the queue fully drains (backfill never starves the head), and
    /// (c) every dispatched slice has exactly the job's width.
    #[test]
    fn prop_backfill_is_starvation_free_and_never_double_books() {
        let mut rng = Rng::new(2026);
        for trial in 0..40 {
            let mut h = Head::new();
            // 4 hosts x 12 slots = 48; every job individually fits
            h.hostfile_text =
                "10.0.0.1 slots=12\n10.0.0.2 slots=12\n10.0.0.3 slots=12\n10.0.0.4 slots=12\n"
                    .to_string();
            let total = h.slots_available();
            let n_jobs = 5 + rng.gen_range(15) as u32;
            for i in 0..n_jobs {
                let ranks = 1 + rng.gen_range(total as u64) as u32;
                h.submit(job(i, ranks), SimTime::ZERO);
            }
            let mut started = 0u32;
            let mut steps = 0u32;
            while started < n_jobs {
                steps += 1;
                assert!(steps < 10 * n_jobs + 100, "trial {trial}: scheduler wedged");
                while let Some(s) = h.start_next(SimTime::from_secs(steps as u64)) {
                    assert_eq!(s.hostfile_slice.total_slots(), s.spec.ranks, "trial {trial}");
                    started += 1;
                }
                assert!(h.overbooked_hosts().is_empty(), "trial {trial}: double-booked");
                // complete one random running job so slots churn
                let ids: Vec<JobId> = h.running.keys().copied().collect();
                if let Some(id) = rng.choose(&ids) {
                    h.finish(*id);
                }
            }
            assert!(h.queue.is_empty(), "trial {trial}: queue never drained");
        }
    }

    /// EASY admits a backfill the conservative guard refuses, because
    /// the running jobs' known runtimes prove it finishes before the
    /// blocked head job's reservation.
    #[test]
    fn easy_backfill_uses_known_runtimes() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::easy();
        h.hostfile_text = "10.10.0.2 slots=16\n10.10.0.3 slots=16\n".into();
        h.submit(jobd(0, 20, 100), SimTime::ZERO);
        let _ = h.start_next(SimTime::ZERO).unwrap(); // 12 free until t=100
        h.submit(jobd(1, 24, 60), SimTime::ZERO); // head, blocked
        h.submit(jobd(2, 10, 30), SimTime::ZERO); // 24+10 > 32: fifo refuses
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(2), "EASY must admit the short job");
        assert!(r.backfilled);
        // a job predicted to outlive the reservation (and wider than
        // the head job's spare slots) must wait
        h.submit(jobd(3, 10, 500), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_none());
        assert!(h.overbooked_hosts().is_empty());
    }

    #[test]
    fn priority_policy_dispatches_highest_priority_first() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(jobp(0, 8, 10, 0), SimTime::ZERO);
        h.submit(jobp(1, 8, 10, 3), SimTime::ZERO);
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(1), "higher priority runs first");
        assert!(!r.backfilled, "the priority head is not a backfill");
    }

    /// A blocked high-priority arrival checkpoints-and-requeues the
    /// lowest-priority running job when that frees enough slots — and
    /// the victim keeps its elapsed-time credit.
    #[test]
    fn preemption_frees_slots_for_high_priority_work() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        h.submit(jobp(0, 24, 100, 0), SimTime::ZERO);
        let first = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(first.spec.id, JobId::new(0));
        h.submit(jobp(1, 24, 30, 5), SimTime::from_secs(40));
        let r = h.start_next(SimTime::from_secs(40)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1), "urgent job must start");
        assert_eq!(r.preempted, vec![JobId::new(0)]);
        assert_eq!(r.preempt_wasted, SimTime::ZERO, "synthetic waste is 0");
        assert!(h.overbooked_hosts().is_empty());
        // the victim is queued with 40s of its 100s credited
        let (spec, _) = h.queue.front().unwrap();
        assert_eq!(spec.id, JobId::new(0));
        match &spec.kind {
            JobKind::Synthetic { duration } => {
                assert_eq!(*duration, SimTime::from_secs(60), "elapsed time credited")
            }
            other => panic!("kind changed: {other:?}"),
        }
        // equal or higher priority running work is never a victim
        h.submit(jobp(2, 24, 10, 5), SimTime::from_secs(41));
        assert!(h.start_next(SimTime::from_secs(41)).is_none());
    }

    /// Preemption advances the attempt generation (so a stale
    /// completion event cannot complete the requeued job) but does not
    /// charge the fault retry budget.
    #[test]
    fn preemption_bumps_attempt_without_charging_retry_budget() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.max_retries = 0; // ANY fault loss abandons immediately
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(jobp(0, 24, 100, 0), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        h.submit(jobp(1, 24, 10, 9), SimTime::from_secs(10));
        let r = h.start_next(SimTime::from_secs(10)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1));
        assert_eq!(r.preempted, vec![JobId::new(0)]);
        h.finish(JobId::new(1));
        // the victim redispatches at generation 1 even though its
        // retry budget (0) is untouched
        let again = h.start_next(SimTime::from_secs(20)).unwrap();
        assert_eq!(again.spec.id, JobId::new(0));
        assert_eq!(again.attempt, 1, "preemption must advance the generation");
        // a real node loss now abandons it (budget 0), proving the
        // preemption above never spent budget
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(21), "died");
        assert_eq!(out, LossOutcome::Abandoned { id: JobId::new(0) });
    }

    /// At the concurrency cap, a preempting policy may still swap
    /// running work: preempt + start keeps the job count constant.
    #[test]
    fn preemption_swaps_work_at_the_concurrency_cap() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.max_concurrent = 1;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(jobp(0, 24, 100, 0), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        h.submit(jobp(1, 24, 10, 5), SimTime::from_secs(10));
        let r = h.start_next(SimTime::from_secs(10)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1), "urgent must swap in at the cap");
        assert_eq!(r.preempted, vec![JobId::new(0)]);
        assert_eq!(h.running.len(), 1, "swap must not exceed the cap");
        // a non-preempting policy at the cap still refuses to start
        let mut serial = Head::new();
        serial.max_concurrent = 1;
        serial.hostfile_text = "10.10.0.2 slots=24\n".into();
        serial.submit(job(0, 4), SimTime::ZERO);
        serial.submit(job(1, 4), SimTime::ZERO);
        assert!(serial.start_next(SimTime::ZERO).is_some());
        assert!(serial.start_next(SimTime::ZERO).is_none());
    }

    #[test]
    fn topo_aware_head_packs_reservations_into_one_rack() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy {
            kind: PolicyKind::Fifo,
            preemption: false,
            topo_aware: true,
        };
        h.hostfile_text =
            "10.10.0.2 slots=12\n10.10.0.3 slots=12\n10.10.0.4 slots=12\n".into();
        // hosts .2 -> rack0, .3/.4 -> rack1
        h.rack_of.insert(Ipv4::parse("10.10.0.2").unwrap(), 0);
        h.rack_of.insert(Ipv4::parse("10.10.0.3").unwrap(), 1);
        h.rack_of.insert(Ipv4::parse("10.10.0.4").unwrap(), 1);
        h.submit(job(0, 24), SimTime::ZERO);
        let r = h.start_next(SimTime::ZERO).unwrap();
        let racks: HashSet<usize> = r
            .hostfile_slice
            .hosts
            .iter()
            .map(|s| h.rack_of[&s.addr])
            .collect();
        assert_eq!(racks, HashSet::from([1]), "24 ranks fit rack1 alone: {r:?}");
        assert_eq!(r.hostfile_slice.total_slots(), 24);
        assert!(h.overbooked_hosts().is_empty());
    }

    #[test]
    fn weighted_queued_slots_scales_with_priority() {
        let mut h = Head::new();
        h.submit(jobp(0, 12, 10, 0), SimTime::ZERO);
        assert_eq!(h.weighted_queued_slots(), h.queued_slots());
        h.submit(jobp(1, 12, 10, 2), SimTime::ZERO); // weight 2.0
        assert_eq!(h.queued_slots(), 24);
        assert_eq!(h.weighted_queued_slots(), 12 + 24);
    }
}
