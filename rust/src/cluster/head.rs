//! Head-node state: the job queue and the consul-template hostfile
//! watcher (the paper's Fig. 5 loop lives here).

use crate::consul::template::{Template, TemplateWatcher};
use crate::mpi::hostfile::Hostfile;
use crate::sim::SimTime;
use crate::util::ids::JobId;
use std::collections::VecDeque;

/// What kind of work a job is.
#[derive(Debug, Clone)]
pub enum JobKind {
    /// Real distributed Jacobi solve (PJRT compute on rank threads).
    Jacobi { px: usize, py: usize, tile: usize, steps: usize },
    /// Synthetic job with a fixed virtual duration (for control-plane
    /// benches where real compute would only add noise).
    Synthetic { duration: SimTime },
}

/// A submitted job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    pub ranks: u32,
    pub kind: JobKind,
}

/// Lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Queued,
    Running { started: SimTime },
    Done { started: SimTime, finished: SimTime },
    Failed { reason: String },
}

/// Completed-job record.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub spec: JobSpec,
    pub state: JobState,
    /// For Jacobi jobs: (steps, final residual).
    pub result: Option<(usize, f32)>,
    pub queued_at: SimTime,
}

/// The head container's state.
pub struct Head {
    pub watcher: TemplateWatcher,
    pub hostfile_text: String,
    /// When the hostfile last changed.
    pub hostfile_updated_at: SimTime,
    pub hostfile_renders: u64,
    pub queue: VecDeque<(JobSpec, SimTime)>,
    pub running: Option<JobRecord>,
    pub completed: Vec<JobRecord>,
    pub poll_interval: SimTime,
}

impl Default for Head {
    fn default() -> Self {
        Self::new()
    }
}

impl Head {
    pub fn new() -> Self {
        Self {
            watcher: TemplateWatcher::new(Template::mpi_hostfile()),
            hostfile_text: String::new(),
            hostfile_updated_at: SimTime::ZERO,
            hostfile_renders: 0,
            queue: VecDeque::new(),
            running: None,
            completed: Vec::new(),
            poll_interval: SimTime::from_millis(200),
        }
    }

    /// Parse the current hostfile (None when empty/invalid).
    pub fn hostfile(&self) -> Option<Hostfile> {
        Hostfile::parse(&self.hostfile_text).ok()
    }

    /// Total MPI slots currently advertised.
    pub fn slots_available(&self) -> u32 {
        self.hostfile().map(|h| h.total_slots()).unwrap_or(0)
    }

    /// Slots demanded by queued + running jobs.
    pub fn demanded_slots(&self) -> u32 {
        let q: u32 = self.queue.iter().map(|(j, _)| j.ranks).sum();
        let r = self
            .running
            .as_ref()
            .map(|j| j.spec.ranks)
            .unwrap_or(0);
        q + r
    }

    pub fn submit(&mut self, spec: JobSpec, now: SimTime) {
        self.queue.push_back((spec, now));
    }

    /// Pop the next runnable job if enough slots are advertised.
    pub fn next_runnable(&mut self, now: SimTime) -> Option<JobRecord> {
        if self.running.is_some() {
            return None;
        }
        let slots = self.slots_available();
        match self.queue.front() {
            Some((job, _)) if job.ranks <= slots => {
                let (spec, queued_at) = self.queue.pop_front().unwrap();
                Some(JobRecord {
                    spec,
                    state: JobState::Running { started: now },
                    result: None,
                    queued_at,
                })
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u32, ranks: u32) -> JobSpec {
        JobSpec {
            id: JobId::new(id),
            name: format!("job{id}"),
            ranks,
            kind: JobKind::Synthetic { duration: SimTime::from_secs(10) },
        }
    }

    #[test]
    fn jobs_wait_for_slots() {
        let mut h = Head::new();
        h.submit(job(0, 16), SimTime::ZERO);
        assert!(h.next_runnable(SimTime::ZERO).is_none(), "no hostfile yet");
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        let r = h.next_runnable(SimTime::from_secs(1)).unwrap();
        assert_eq!(r.spec.id, JobId::new(0));
        assert!(matches!(r.state, JobState::Running { .. }));
    }

    #[test]
    fn one_job_at_a_time() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 4), SimTime::ZERO);
        h.submit(job(1, 4), SimTime::ZERO);
        let r = h.next_runnable(SimTime::ZERO).unwrap();
        h.running = Some(r);
        assert!(h.next_runnable(SimTime::ZERO).is_none());
    }

    #[test]
    fn demanded_slots_counts_queue_and_running() {
        let mut h = Head::new();
        h.submit(job(0, 16), SimTime::ZERO);
        h.submit(job(1, 8), SimTime::ZERO);
        assert_eq!(h.demanded_slots(), 24);
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        let r = h.next_runnable(SimTime::ZERO).unwrap();
        h.running = Some(r);
        assert_eq!(h.demanded_slots(), 24);
    }

    #[test]
    fn fifo_order_holds() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=32\n".into();
        h.submit(job(0, 32), SimTime::ZERO);
        h.submit(job(1, 1), SimTime::ZERO);
        // head-of-line blocks even though job1 would fit
        let r = h.next_runnable(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(0));
    }
}
