//! Head-node state: the job queue, the slot-aware concurrent scheduler
//! and the consul-template hostfile watcher (the paper's Fig. 5 loop
//! lives here).
//!
//! Scheduling model: the hostfile advertises `slots` per compute node.
//! Each running job holds a *reservation* — a slice of specific host
//! slots carved out of the current hostfile — so any number of jobs can
//! run concurrently without two jobs ever sharing an advertised slot.
//! *Which* queued job is dispatched next, and whether a blocked
//! high-priority job may preempt running work, is delegated to the
//! head's [`SchedulePolicy`](crate::cluster::policy::SchedulePolicy):
//! FIFO + conservative backfill (the default, starvation-free without
//! runtime knowledge), EASY backfill (reservation-based, using the
//! jobs' known or estimated runtimes), or priority order with optional
//! preemption. Reservation *placement* is hostfile-order by default or
//! rack-packing when the policy is topology-aware.
//!
//! Two per-job counters are deliberately distinct: the **attempt
//! generation** advances on every early exit from the running pool
//! (fault requeue or preemption) and guards stale completion events,
//! while the **fault retry budget** is charged only when a node loss
//! kills the job — being preempted is the scheduler's choice and must
//! not count against the job.
//!
//! Every job carries its **tenant** ([`JobSpec::tenant`], 0 =
//! untenanted). The head accrues running reservations into the
//! [`UsageLedger`](crate::tenancy::ledger::UsageLedger) (what the
//! `fairshare` policy orders by), enforces per-tenant
//! [`TenantQuotas`](crate::tenancy::ledger::TenantQuotas) at submit
//! (queued-job cap: reject or defer) and at dispatch (running-slot
//! cap: the job waits without blocking other tenants), and keeps the
//! attribution across every requeue path — fault retries and
//! preemptions charge the same tenant as the original run.

use crate::cluster::policy::{Decision, PolicyKind, SchedulePolicy};
use crate::consul::template::{Template, TemplateWatcher};
use crate::mpi::hostfile::{HostSlot, Hostfile};
use crate::sim::SimTime;
use crate::tenancy::ledger::{QuotaAction, TenantQuotas, UsageLedger};
use crate::util::ids::JobId;
use crate::vnet::addr::Ipv4;
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

/// What kind of work a job is.
#[derive(Debug, Clone, PartialEq)]
pub enum JobKind {
    /// Real distributed Jacobi solve (PJRT compute on rank threads).
    Jacobi { px: usize, py: usize, tile: usize, steps: usize },
    /// Synthetic job with a fixed virtual duration (for control-plane
    /// benches where real compute would only add noise).
    Synthetic { duration: SimTime },
}

/// Default Jacobi restart-checkpoint interval, in solver steps: a job
/// requeued after losing a node (or preempted) resumes from the last
/// completed multiple of [`Head::checkpoint_every_steps`], which
/// defaults to this. Historically the residual cadence doubled as the
/// checkpoint; the two are now decoupled — see
/// [`JACOBI_RESIDUAL_CHECK_STEPS`] — so partial-progress credit and
/// preemption cost are tunable without touching the numerics.
pub const JACOBI_CHECKPOINT_STEPS: usize = 20;

/// Default cap on the in-memory completed-job history (and therefore on
/// the HA snapshot's completed section). Far above any driver trace,
/// but finite: a long-lived head no longer grows without bound.
pub const DEFAULT_COMPLETED_RETENTION: usize = 10_000;

/// Jacobi residual-check (allreduce) cadence, in solver steps — a
/// numerical-reporting knob only. Restart checkpoints are governed by
/// [`Head::checkpoint_every_steps`].
pub const JACOBI_RESIDUAL_CHECK_STEPS: usize = 20;

/// A submitted job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    pub ranks: u32,
    pub kind: JobKind,
    /// Scheduling priority: higher runs sooner under the priority
    /// policy; 0 is normal batch work. Ignored by FIFO/EASY dispatch
    /// order but always feeds the autoscaler's weighted demand signal.
    pub priority: i32,
    /// Owning tenant (0 = untenanted system work). Preserved across
    /// fault requeues and preemptions, so every rerun charges the same
    /// ledger account and counts against the same quotas.
    pub tenant: u64,
}

/// What [`Head::submit`] did with a submission.
#[derive(Debug, Clone)]
pub enum SubmitOutcome {
    /// In the queue, visible to the dispatch policy.
    Queued,
    /// The tenant is over its queued-job quota and the quota action is
    /// [`QuotaAction::Defer`]: parked in the per-tenant holding pen,
    /// admitted automatically once the tenant is back under quota.
    Deferred,
    /// The tenant is over its queued-job quota and the quota action is
    /// [`QuotaAction::Reject`]: the spec is handed back so the caller
    /// can record the failure.
    Rejected { spec: JobSpec, reason: String },
}

impl JobSpec {
    /// Planning estimate of the job's virtual runtime, used by EASY
    /// backfill to compute the blocked head job's reservation.
    /// Synthetic durations are known exactly (for a requeued job the
    /// stored duration is already the remaining work); Jacobi uses a
    /// coarse per-step cost model scaled by the tile area.
    pub fn estimated_duration(&self) -> SimTime {
        match &self.kind {
            JobKind::Synthetic { duration } => *duration,
            JobKind::Jacobi { tile, steps, .. } => {
                let per_step_ms = ((tile * tile) as u64 / 1024).max(1);
                SimTime::from_millis(per_step_ms * (*steps).max(1) as u64)
            }
        }
    }
}

/// Lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Queued,
    Running { started: SimTime },
    Done { started: SimTime, finished: SimTime },
    Failed { reason: String },
}

/// Per-job record (running or completed).
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub spec: JobSpec,
    pub state: JobState,
    /// For Jacobi jobs: (steps, final residual).
    pub result: Option<(usize, f32)>,
    pub queued_at: SimTime,
    /// How many times this job has already been requeued after losing a
    /// node (0 = first run).
    pub attempt: u32,
    /// Virtual duration the dispatcher scheduled for this attempt (set
    /// at launch; used to prorate progress credit when the job is lost).
    pub planned_duration: Option<SimTime>,
}

impl JobRecord {
    /// When the dispatcher expects this job's slots back: its start
    /// plus the planned duration (or the spec's estimate before the
    /// launch pins one), clamped to `now` for overdue jobs. This is
    /// the signal EASY backfill builds the head job's reservation
    /// from — a job that dies takes its prediction with it, because
    /// the policy recomputes from the live running pool every time.
    pub fn predicted_finish(&self, now: SimTime) -> SimTime {
        let started = match self.state {
            JobState::Running { started } => started,
            _ => now,
        };
        let dur = self
            .planned_duration
            .unwrap_or_else(|| self.spec.estimated_duration());
        (started + dur).max(now)
    }
}

/// A job the scheduler just dispatched: its spec plus the hostfile slice
/// reserved for it (what `mpirun --hostfile` gets for this job).
#[derive(Debug, Clone)]
pub struct StartedJob {
    pub spec: JobSpec,
    pub queued_at: SimTime,
    pub hostfile_slice: Hostfile,
    /// True when the job overtook the head-of-queue job via backfill.
    pub backfilled: bool,
    /// Which attempt this dispatch is (guards completion events from
    /// earlier attempts of the same job).
    pub attempt: u32,
    /// Jobs checkpointed-and-requeued to make room for this one
    /// (non-empty only under the priority policy with preemption).
    pub preempted: Vec<JobId>,
    /// Virtual work the preempted jobs' reruns must redo (their
    /// progress past the last checkpoint).
    pub preempt_wasted: SimTime,
}

/// What the head did with a running job whose reservation lost a node.
#[derive(Debug, Clone, PartialEq)]
pub enum LossOutcome {
    /// Requeued at the head of the queue with partial-progress credit.
    /// `wasted` is the virtual work the rerun must redo (credit gap).
    Requeued { id: JobId, attempt: u32, wasted: SimTime },
    /// Retry budget exhausted: recorded as permanently failed.
    Abandoned { id: JobId },
    /// The job was not in the running pool (already finished or reaped).
    NotRunning,
}

/// Memoized policy-facing queue view (see [`Head::refresh_queue_view`]).
///
/// `eligible[i]` is the index into `Head::queue` that `view[i]`
/// describes, so a `Decision::Start { idx }` maps back to the real
/// queue through `eligible[idx]` exactly as the uncached code did.
struct QueueViewCache {
    /// False until first build and after any mutation of the view's
    /// structural inputs (see [`Head::dirty_queue_view`]).
    valid: bool,
    /// Decision time the cached `usage` figures were computed at.
    as_of: SimTime,
    /// [`UsageLedger::version`] the `usage` figures were computed from.
    ledger_version: u64,
    /// Running-slot quota the eligibility filter was computed under.
    quota_cap: u32,
    /// Indices into `Head::queue` of the quota-eligible jobs, in queue
    /// order.
    eligible: Vec<usize>,
    /// The policy-facing view of those jobs.
    view: Vec<crate::cluster::policy::QueuedJob>,
}

/// The head container's state.
pub struct Head {
    pub watcher: TemplateWatcher,
    pub hostfile_text: String,
    /// When the hostfile last changed.
    pub hostfile_updated_at: SimTime,
    pub hostfile_renders: u64,
    pub queue: VecDeque<(JobSpec, SimTime)>,
    /// Concurrently running jobs, keyed by id.
    pub running: HashMap<JobId, JobRecord>,
    /// Per-job slot reservations (slices of the advertised hostfile).
    reserved: HashMap<JobId, Vec<HostSlot>>,
    pub completed: Vec<JobRecord>,
    /// Cap on `completed`: once exceeded, the oldest records are
    /// dropped and counted in `completed_trimmed`. `0` = unlimited.
    /// Record terminal jobs through [`Head::record_terminal`] so the
    /// cap is enforced on every path (live, WAL replay, restore).
    pub completed_retention: usize,
    /// Completed records dropped by the retention cap — keeps
    /// [`Head::completed_total`] monotonic for driver progress checks.
    pub completed_trimmed: u64,
    /// When the autoscaler last scaled up / retired nodes. Journaled
    /// through the WAL so a takeover re-arms the per-direction
    /// cooldowns instead of granting itself a free scaling action.
    pub last_scale_up: Option<SimTime>,
    pub last_scale_down: Option<SimTime>,
    pub poll_interval: SimTime,
    /// Cap on concurrent jobs (`usize::MAX` = slot-limited only). Set to
    /// 1 to reproduce the old one-job-at-a-time head for comparisons.
    pub max_concurrent: usize,
    /// How many times a job may be requeued after losing a node before
    /// it is recorded as permanently failed.
    pub max_retries: u32,
    /// Dispatch-order + placement policy (see
    /// [`SchedulePolicy`](crate::cluster::policy::SchedulePolicy));
    /// the default reproduces the pre-policy FIFO head exactly.
    pub policy: SchedulePolicy,
    /// Per-tenant decayed slot-second usage — what the `fairshare`
    /// policy orders the queue by. Accrued from running reservations by
    /// [`Head::accrue_usage`].
    pub ledger: UsageLedger,
    /// Per-tenant limits (default unlimited: the pre-tenancy head).
    pub quotas: TenantQuotas,
    /// Jacobi restart-checkpoint interval in solver steps: a requeued or
    /// preempted Jacobi job resumes from the last completed multiple of
    /// this. Smaller = cheaper preemption, more frequent (virtual)
    /// checkpoint I/O. Defaults to [`JACOBI_CHECKPOINT_STEPS`].
    pub checkpoint_every_steps: usize,
    /// Per-tenant holding pens for submissions deferred by the
    /// queued-job quota ([`QuotaAction::Defer`]), FIFO within a tenant.
    /// Deliberately invisible to the queue metrics and the autoscaler's
    /// demand signal: a flood past quota must not provision capacity.
    deferred: BTreeMap<u64, VecDeque<(JobSpec, SimTime)>>,
    /// High-water mark of [`Head::accrue_usage`] (usage is charged for
    /// the interval since this).
    last_accrued: SimTime,
    /// Host address -> rack index, for topology-aware placement and
    /// the per-job rack-spread metric. Populated by the cluster as
    /// containers come up; unknown hosts share one pseudo-rack.
    pub rack_of: HashMap<Ipv4, usize>,
    /// Fault-retry budget consumed per job. Charged only by
    /// [`Head::handle_lost_job`]; entries cleared on completion.
    retries: HashMap<JobId, u32>,
    /// Attempt generation per job: advanced by *every* early exit from
    /// the running pool — fault requeue or preemption — so a stale
    /// completion event can never complete a newer attempt. Always
    /// >= the retry budget spent.
    attempts: HashMap<JobId, u32>,
    /// Jacobi steps credited from prior attempts (the resume point).
    jacobi_progress: HashMap<JobId, usize>,
    /// When each job first lost a node — MTTR is measured from here to
    /// the job's eventual completion. Cleared on completion/abandonment.
    pub first_failed_at: HashMap<JobId, SimTime>,
    /// The tenant arrival generator's last journaled resume cursor
    /// (None outside `vhpc tenants` runs). Carried through WAL replay
    /// and snapshots so a takeover continues the arrival stream exactly
    /// where the dead head left it.
    pub last_arrival_cursor: Option<String>,
    /// In-memory buffer of not-yet-flushed WAL events (`None` = HA
    /// journaling off, the default — zero cost on non-HA clusters).
    /// Mutation methods push into it; the cluster drains it into the
    /// replicated log at the end of every engine event via
    /// [`Head::take_journal`].
    journal: Option<Vec<crate::ha::wal::WalEvent>>,
    /// Cached policy queue view, rebuilt lazily by
    /// [`Head::refresh_queue_view`] and invalidated by
    /// [`Head::dirty_queue_view`] wherever the queue, the running pool
    /// or the deferral pens change. Ledger drift and the passage of
    /// time refresh in place (usage only) instead of invalidating.
    view_cache: QueueViewCache,
}

impl Default for Head {
    fn default() -> Self {
        Self::new()
    }
}

impl Head {
    pub fn new() -> Self {
        Self {
            watcher: TemplateWatcher::new(Template::mpi_hostfile()),
            hostfile_text: String::new(),
            hostfile_updated_at: SimTime::ZERO,
            hostfile_renders: 0,
            queue: VecDeque::new(),
            running: HashMap::new(),
            reserved: HashMap::new(),
            completed: Vec::new(),
            completed_retention: DEFAULT_COMPLETED_RETENTION,
            completed_trimmed: 0,
            last_scale_up: None,
            last_scale_down: None,
            poll_interval: SimTime::from_millis(200),
            max_concurrent: usize::MAX,
            max_retries: 3,
            policy: SchedulePolicy::default(),
            ledger: UsageLedger::default(),
            quotas: TenantQuotas::default(),
            checkpoint_every_steps: JACOBI_CHECKPOINT_STEPS,
            deferred: BTreeMap::new(),
            last_accrued: SimTime::ZERO,
            rack_of: HashMap::new(),
            retries: HashMap::new(),
            attempts: HashMap::new(),
            jacobi_progress: HashMap::new(),
            first_failed_at: HashMap::new(),
            last_arrival_cursor: None,
            journal: None,
            view_cache: QueueViewCache {
                valid: false,
                as_of: SimTime::ZERO,
                ledger_version: 0,
                quota_cap: u32::MAX,
                eligible: Vec::new(),
                view: Vec::new(),
            },
        }
    }

    /// Turn on HA journaling: every subsequent state mutation buffers a
    /// [`WalEvent`](crate::ha::wal::WalEvent) for the cluster to flush
    /// into the replicated log.
    pub fn enable_journal(&mut self) {
        if self.journal.is_none() {
            self.journal = Some(Vec::new());
        }
    }

    pub fn journal_enabled(&self) -> bool {
        self.journal.is_some()
    }

    /// Drain the buffered WAL events (empty when journaling is off).
    pub fn take_journal(&mut self) -> Vec<crate::ha::wal::WalEvent> {
        self.journal.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Append a cluster-level event (launch, completion, terminal
    /// failure) into the journal — the head's own mutations log
    /// themselves.
    pub(crate) fn log_event(&mut self, ev: crate::ha::wal::WalEvent) {
        self.log(ev);
    }

    fn log(&mut self, ev: crate::ha::wal::WalEvent) {
        if let Some(j) = self.journal.as_mut() {
            j.push(ev);
        }
    }

    /// Parse the current hostfile (None when empty/invalid).
    pub fn hostfile(&self) -> Option<Hostfile> {
        Hostfile::parse(&self.hostfile_text).ok()
    }

    /// Total MPI slots currently advertised.
    pub fn slots_available(&self) -> u32 {
        self.hostfile().map(|h| h.total_slots()).unwrap_or(0)
    }

    /// Slots held by running jobs' reservations.
    pub fn reserved_slots(&self) -> u32 {
        self.running.values().map(|r| r.spec.ranks).sum() // lint: allow(map-iter) u32 sum, order-independent
    }

    /// Slots demanded by jobs still waiting in the queue.
    pub fn queued_slots(&self) -> u32 {
        self.queue.iter().map(|(j, _)| j.ranks).sum()
    }

    /// Slots demanded by queued + running jobs.
    pub fn demanded_slots(&self) -> u32 {
        self.queued_slots() + self.reserved_slots()
    }

    /// Advertised slots not reserved by any running job.
    pub fn free_slots(&self) -> u32 {
        self.free_per_host().iter().map(|h| h.slots).sum()
    }

    /// Per-host free capacity: advertised slots minus reservations, in
    /// hostfile order. Hosts that left the hostfile contribute nothing;
    /// reservations pointing at them are simply unmatched.
    fn free_per_host(&self) -> Vec<HostSlot> {
        let hf = match self.hostfile() {
            Some(hf) => hf,
            None => return Vec::new(),
        };
        let held = self.reserved_per_host();
        hf.hosts
            .into_iter()
            .map(|h| HostSlot {
                addr: h.addr,
                slots: h.slots.saturating_sub(held.get(&h.addr).copied().unwrap_or(0)),
            })
            .collect()
    }

    /// Reserved slot count per host address (for overbooking checks).
    pub fn reserved_per_host(&self) -> HashMap<Ipv4, u32> {
        let mut held: HashMap<Ipv4, u32> = HashMap::new();
        for slice in self.reserved.values() { // lint: allow(map-iter) commutative accumulation into a map
            for h in slice {
                *held.entry(h.addr).or_insert(0) += h.slots;
            }
        }
        held
    }

    /// Host addresses with at least one reserved slot (nodes the cluster
    /// must not retire while jobs hold them).
    pub fn reserved_addrs(&self) -> HashSet<Ipv4> {
        self.reserved
            .values() // lint: allow(map-iter) collected into a set, order-free
            .flat_map(|slice| slice.iter().map(|h| h.addr))
            .collect()
    }

    /// Hosts where reservations exceed the advertised slot count. Always
    /// empty unless a reserved host shrank or left the hostfile.
    pub fn overbooked_hosts(&self) -> Vec<Ipv4> {
        let advertised: HashMap<Ipv4, u32> = self
            .hostfile()
            .map(|hf| hf.hosts.into_iter().map(|h| (h.addr, h.slots)).collect())
            .unwrap_or_default();
        self.reserved_per_host()
            .into_iter()
            .filter(|(addr, held)| *held > advertised.get(addr).copied().unwrap_or(0))
            .map(|(addr, _)| addr)
            .collect()
    }

    /// Submit a job, enforcing the tenant's quotas: under quota it
    /// queues; over the queued-job quota it is rejected (spec handed
    /// back) or parked in the tenant's deferral pen, per
    /// [`TenantQuotas::over_quota`]. A job wider than the tenant's
    /// running-slot quota is always rejected — it could never dispatch
    /// and would sit invisible forever. Deterministic — the decision
    /// depends only on current queue/pen contents and the quota config.
    pub fn submit(&mut self, spec: JobSpec, now: SimTime) -> SubmitOutcome {
        if self.journal.is_some() {
            // log the arrival, not the outcome: replay re-runs this very
            // quota machinery against identical state, so queued /
            // deferred / rejected all reproduce
            let ev = crate::ha::wal::WalEvent::Submitted { at: now, spec: spec.clone() };
            self.log(ev);
        }
        let tenant = spec.tenant;
        if spec.ranks > self.quotas.max_running_slots {
            return SubmitOutcome::Rejected {
                reason: format!(
                    "job needs {} slots but tenant {tenant}'s running-slot quota is {}",
                    spec.ranks, self.quotas.max_running_slots
                ),
                spec,
            };
        }
        let cap = self.quotas.max_queued_jobs;
        // Under Defer, a non-empty pen must also divert new work: a
        // fresh submission sneaking into a just-freed queue slot would
        // overtake earlier deferred jobs and starve the pen.
        let pen_waiting = self.quotas.over_quota == QuotaAction::Defer
            && self.deferred.get(&tenant).map(|p| !p.is_empty()).unwrap_or(false);
        // the O(queue) count only runs when a finite quota can trigger —
        // the default unlimited config keeps submit O(1)
        let over_cap = cap != usize::MAX && self.tenant_queued_jobs(tenant) >= cap;
        if over_cap || pen_waiting {
            // A 0-job queue cap can never admit from the pen
            // (`admit_deferred` requires queued < cap): deferring would
            // strand the job invisibly forever, so it degenerates to a
            // recorded rejection.
            if self.quotas.over_quota == QuotaAction::Reject || cap == 0 {
                return SubmitOutcome::Rejected {
                    reason: format!(
                        "tenant {tenant} is over its queued-job quota ({cap})"
                    ),
                    spec,
                };
            }
            self.deferred.entry(tenant).or_default().push_back((spec, now));
            return SubmitOutcome::Deferred;
        }
        self.queue.push_back((spec, now));
        self.dirty_queue_view();
        SubmitOutcome::Queued
    }

    /// Jobs a tenant currently has waiting in the queue (deferred jobs
    /// excluded — they are not queued yet).
    pub fn tenant_queued_jobs(&self, tenant: u64) -> usize {
        self.queue.iter().filter(|(j, _)| j.tenant == tenant).count()
    }

    /// Slots a tenant's running jobs currently hold.
    pub fn tenant_running_slots(&self, tenant: u64) -> u32 {
        self.running
            .values() // lint: allow(map-iter) u32 sum, order-independent
            .filter(|r| r.spec.tenant == tenant)
            .map(|r| r.spec.ranks)
            .sum()
    }

    /// Running-slot totals for every tenant with running work — the
    /// shared aggregation behind the dispatch quota gate and the
    /// autoscaler demand clamp (one pass over the running pool).
    fn running_slots_by_tenant(&self) -> HashMap<u64, u32> {
        let mut by_tenant: HashMap<u64, u32> = HashMap::new();
        for r in self.running.values() { // lint: allow(map-iter) commutative accumulation into a map
            *by_tenant.entry(r.spec.tenant).or_insert(0) += r.spec.ranks;
        }
        by_tenant
    }

    /// Jobs parked in deferral pens across all tenants.
    pub fn deferred_jobs(&self) -> usize {
        self.deferred.values().map(|q| q.len()).sum()
    }

    /// Move deferred jobs back into the queue for every tenant that is
    /// under its queued-job quota again (FIFO within a tenant, tenants
    /// in id order — deterministic). Returns how many were admitted.
    /// Called automatically at the top of [`Head::start_next`].
    pub fn admit_deferred(&mut self) -> u64 {
        if self.deferred.is_empty() {
            return 0;
        }
        let mut admitted = 0;
        let tenants: Vec<u64> = self.deferred.keys().copied().collect();
        for t in tenants {
            // count the tenant's queued jobs once, then track admissions
            // locally — re-scanning the queue per admitted job would be
            // O(queue x admissions) on every dispatch attempt
            let mut queued = self.tenant_queued_jobs(t);
            while queued < self.quotas.max_queued_jobs {
                let Some(pen) = self.deferred.get_mut(&t) else { break };
                let Some((spec, at)) = pen.pop_front() else { break };
                self.queue.push_back((spec, at));
                queued += 1;
                admitted += 1;
            }
            if self.deferred.get(&t).map(|p| p.is_empty()).unwrap_or(false) {
                self.deferred.remove(&t);
            }
        }
        if admitted > 0 {
            self.dirty_queue_view();
        }
        admitted
    }

    /// Charge every running reservation's slot-seconds since the last
    /// accrual into the tenant ledger. Called on every dispatch attempt
    /// and before completions/losses/preemptions leave the running
    /// pool, so no held interval escapes accounting. Charges are summed
    /// in job-id order: f64 addition is order-sensitive and the
    /// hash-ordered running pool must not leak into the fingerprint.
    pub fn accrue_usage(&mut self, now: SimTime) {
        let dt = now.saturating_sub(self.last_accrued);
        if dt == SimTime::ZERO {
            return;
        }
        let mut charges: Vec<(JobId, u64, f64)> = self
            .running
            .values() // lint: sorted
            .filter_map(|r| {
                let started = match r.state {
                    JobState::Running { started } => started,
                    _ => now,
                };
                // a job dispatched mid-interval is charged only from its
                // own start, whatever the accrual cadence
                let overlap = dt.min(now.saturating_sub(started));
                if overlap == SimTime::ZERO {
                    None
                } else {
                    Some((
                        r.spec.id,
                        r.spec.tenant,
                        r.spec.ranks as f64 * overlap.as_secs_f64(),
                    ))
                }
            })
            .collect();
        charges.sort_by_key(|&(id, _, _)| id);
        let charged = !charges.is_empty();
        for (_, tenant, slot_seconds) in charges {
            self.ledger.charge(tenant, slot_seconds, now);
        }
        self.last_accrued = now;
        if charged {
            // empty accruals (idle pool) only advance the high-water
            // mark and need no log entry: the next charged interval's
            // per-job overlap clamps to each job's start either way
            self.log(crate::ha::wal::WalEvent::Accrued { at: now });
        }
        // bound ledger memory: once the account table outgrows a
        // working set, drop accounts whose decayed balance is
        // negligible (deterministic — purely a function of `now`)
        if self.ledger.active_accounts() > 4096 {
            self.ledger.gc(now, 1e-6);
        }
    }

    /// Invalidate the cached policy queue view. Every mutation of the
    /// view's *structural* inputs must call this: queue membership or
    /// order (submit, admit, dispatch, requeue, restore) and the
    /// running pool (finish, preempt, unlaunch, loss — it feeds the
    /// quota eligibility filter). Ledger drift and the passage of time
    /// are deliberately **not** dirty events: the cache tracks those
    /// through [`UsageLedger::version`] and its `as_of` stamp and
    /// refreshes the usage figures in place.
    fn dirty_queue_view(&mut self) {
        self.view_cache.valid = false;
    }

    /// Bring the cached policy queue view up to date for a decision at
    /// `now`. Three tiers, cheapest first:
    ///
    /// 1. **Reuse** — skeleton valid, same decision time, same ledger
    ///    version: nothing to do. This is the steady-state hit for the
    ///    dispatch loop's repeated `start_next` calls within one tick.
    /// 2. **Usage refresh** — skeleton valid but time moved or the
    ///    ledger changed: recompute only the per-job `usage` figures,
    ///    memoizing [`UsageLedger::normalized_usage_at`] per distinct
    ///    tenant. The memoized value is the same pure function of
    ///    `(ledger, tenant, now)` a rebuild would call per job, so the
    ///    refreshed view is bit-identical to a full rebuild.
    /// 3. **Full rebuild** — the cache was dirtied or the running-slot
    ///    quota changed: recompute the eligibility filter and the whole
    ///    view, exactly the computation `start_next` historically did
    ///    inline on every call.
    fn refresh_queue_view(&mut self, now: SimTime) {
        let ledger_version = self.ledger.version();
        let quota_cap = self.quotas.max_running_slots;
        if self.view_cache.valid && self.view_cache.quota_cap == quota_cap {
            if self.view_cache.as_of == now
                && self.view_cache.ledger_version == ledger_version
            {
                return;
            }
            let ledger = &self.ledger;
            let mut usage_of: HashMap<u64, f64> = HashMap::new();
            for q in self.view_cache.view.iter_mut() {
                let tenant = q.tenant;
                q.usage = *usage_of
                    .entry(tenant)
                    .or_insert_with(|| ledger.normalized_usage_at(tenant, now));
            }
            self.view_cache.as_of = now;
            self.view_cache.ledger_version = ledger_version;
            return;
        }
        // Per-tenant running-slot quota gate: filter the view, keep the
        // index map back into the real queue. The default unlimited
        // quota takes the identity fast path — no per-tenant
        // bookkeeping on pre-tenancy workloads.
        let eligible: Vec<usize> = if quota_cap == u32::MAX {
            (0..self.queue.len()).collect()
        } else {
            let running_by_tenant = self.running_slots_by_tenant();
            let slot_cap = quota_cap as u64;
            (0..self.queue.len())
                .filter(|&i| {
                    let j = &self.queue[i].0;
                    running_by_tenant.get(&j.tenant).copied().unwrap_or(0) as u64
                        + j.ranks as u64
                        <= slot_cap
                })
                .collect()
        };
        let mut usage_of: HashMap<u64, f64> = HashMap::new();
        let view: Vec<crate::cluster::policy::QueuedJob> = eligible
            .iter()
            .map(|&i| {
                let j = &self.queue[i].0;
                crate::cluster::policy::QueuedJob {
                    id: j.id,
                    ranks: j.ranks,
                    priority: j.priority,
                    est: j.estimated_duration(),
                    tenant: j.tenant,
                    usage: *usage_of
                        .entry(j.tenant)
                        .or_insert_with(|| self.ledger.normalized_usage_at(j.tenant, now)),
                }
            })
            .collect();
        self.view_cache = QueueViewCache {
            valid: true,
            as_of: now,
            ledger_version,
            quota_cap,
            eligible,
            view,
        };
    }

    /// Test-only: whether the cached policy queue view is currently
    /// valid (i.e. no structural invalidation since the last build).
    #[doc(hidden)]
    pub fn queue_view_cache_valid(&self) -> bool {
        self.view_cache.valid
    }

    /// Test-only stale-cache injection: stamp the cached view as fresh
    /// for a decision at `now` *without* rebuilding it. The cache
    /// invalidation tests use this to prove they have teeth — after a
    /// mutation, forcing the stale cache clean must visibly change
    /// scheduling, so a missed [`Head::dirty_queue_view`] call cannot
    /// slip through the suite undetected. Never call outside tests.
    #[doc(hidden)]
    pub fn force_queue_view_clean(&mut self, now: SimTime) {
        self.view_cache.valid = true;
        self.view_cache.as_of = now;
        self.view_cache.ledger_version = self.ledger.version();
        self.view_cache.quota_cap = self.quotas.max_running_slots;
    }

    /// Dispatch the next startable job under the configured policy,
    /// reserving its slots. Call in a loop until `None` — each call
    /// starts at most one job (possibly preempting lower-priority
    /// running jobs first; see [`StartedJob::preempted`]). The
    /// returned record is already in `running`. Jobs whose tenant is at
    /// its running-slot quota are invisible to the policy, so an
    /// over-quota job never blocks other tenants' work behind it.
    pub fn start_next(&mut self, now: SimTime) -> Option<StartedJob> {
        // wall-clock phase timer: inert unless the perf harness enabled
        // profiling (virtual time and scheduling are untouched either way)
        let _policy_timer = crate::obs::profiling::scoped("policy_sort");
        if self.admit_deferred() > 0 {
            self.log(crate::ha::wal::WalEvent::Admitted { at: now });
        }
        self.accrue_usage(now);
        let mut preempted: Vec<JobId> = Vec::new();
        let mut preempt_wasted = SimTime::ZERO;
        let may_preempt =
            self.policy.kind == PolicyKind::Priority && self.policy.preemption;
        loop {
            // At the concurrency cap nothing can *start*, but a
            // preempting policy may still swap running work (preempt +
            // start keeps the job count constant), so only short-circuit
            // when no preemption is possible.
            if self.running.len() >= self.max_concurrent && !may_preempt {
                return None;
            }
            // one hostfile parse per dispatch attempt: derive the total
            // and the per-host free pool from the same parsed view
            let hf = self.hostfile()?;
            let total = hf.total_slots();
            let held = self.reserved_per_host();
            let mut free: Vec<HostSlot> = hf
                .hosts
                .into_iter()
                .map(|h| HostSlot {
                    addr: h.addr,
                    slots: h.slots.saturating_sub(held.get(&h.addr).copied().unwrap_or(0)),
                })
                .collect();
            let free_total: u32 = free.iter().map(|h| h.slots).sum();
            if self.queue.is_empty() {
                return None;
            }
            // the policy's queue view is memoized: untouched state hits
            // the cache, ledger/time drift refreshes usage in place, and
            // any structural mutation since the last build triggers the
            // full recompute this used to do inline
            self.refresh_queue_view(now);
            if self.view_cache.eligible.is_empty() {
                return None;
            }
            // sorted by id so every policy sees a deterministic view of
            // the (hash-ordered) running pool
            let mut running_view: Vec<crate::cluster::policy::RunningJob> = self
                .running
                .values() // lint: sorted
                .map(|r| crate::cluster::policy::RunningJob {
                    id: r.spec.id,
                    ranks: r.spec.ranks,
                    priority: r.spec.priority,
                    predicted_finish: r.predicted_finish(now),
                    preempt_waste: self.rerun_plan(r, now).2,
                })
                .collect();
            running_view.sort_by_key(|r| r.id);
            match self.policy.decide(now, &self.view_cache.view, &running_view, free_total, total)
            {
                Decision::Wait => return None,
                Decision::Preempt { victim } => {
                    let (_, wasted) = self.preempt(victim, now)?;
                    preempted.push(victim);
                    preempt_wasted += wasted;
                    // re-decide against the post-preemption state
                }
                Decision::Start { idx, backfilled } => {
                    if self.running.len() >= self.max_concurrent {
                        return None;
                    }
                    let queue_idx = self.view_cache.eligible.get(idx).copied();
                    let Some((spec, queued_at)) =
                        queue_idx.and_then(|qi| self.queue.remove(qi))
                    else {
                        // Policy handed back an index the queue no longer
                        // has. A desync here means a scheduler bug, but the
                        // head must degrade (skip the cycle), not die.
                        log::warn!("start_next: policy index out of range, skipping cycle");
                        return None;
                    };
                    // the job left the queue: whatever happens below
                    // (start or carve-fail requeue), the view is stale
                    self.dirty_queue_view();
                    let carved = if self.policy.topo_aware {
                        crate::cluster::policy::carve_topo(&mut free, spec.ranks, &self.rack_of)
                    } else {
                        carve(&mut free, spec.ranks)
                    };
                    let Some(slice) = carved else {
                        // The policy checked fit before deciding Start; if
                        // the carve still fails, requeue and degrade.
                        log::warn!("start_next: carve failed after fit check, requeueing {}", spec.id);
                        self.queue.push_front((spec, queued_at));
                        return None;
                    };
                    let attempt = self.attempts.get(&spec.id).copied().unwrap_or(0);
                    self.reserved.insert(spec.id, slice.clone());
                    self.running.insert(
                        spec.id,
                        JobRecord {
                            spec: spec.clone(),
                            state: JobState::Running { started: now },
                            result: None,
                            queued_at,
                            attempt,
                            planned_duration: None,
                        },
                    );
                    if self.journal.is_some() {
                        // the one event replay installs directly instead
                        // of re-deciding: the placement depended on the
                        // historical hostfile, so the slice is logged
                        let ev = crate::ha::wal::WalEvent::Dispatched {
                            at: now,
                            id: spec.id,
                            attempt,
                            slice: slice.clone(),
                        };
                        self.log(ev);
                    }
                    return Some(StartedJob {
                        spec,
                        queued_at,
                        hostfile_slice: Hostfile { hosts: slice },
                        backfilled,
                        attempt,
                        preempted,
                        preempt_wasted,
                    });
                }
            }
        }
    }

    /// Remove a job from the running pool, releasing its reservation and
    /// folding progress credited from earlier attempts into its result.
    ///
    /// Takes no timestamp, so it cannot settle the job's final held
    /// interval into the ledger itself — callers that care about usage
    /// accuracy must call [`Head::accrue_usage`] with the completion
    /// time first (the cluster's `job_done` does; [`Head::preempt`] and
    /// [`Head::handle_lost_job`], which do receive `now`, accrue
    /// internally).
    pub fn finish(&mut self, id: JobId) -> Option<JobRecord> {
        self.reserved.remove(&id);
        let mut rec = self.running.remove(&id)?;
        // the running pool feeds the quota eligibility filter
        self.dirty_queue_view();
        self.retries.remove(&id);
        self.attempts.remove(&id);
        if let Some(prior) = self.jacobi_progress.remove(&id) {
            if let Some((steps, residual)) = rec.result {
                rec.result = Some((steps + prior, residual));
            }
        }
        Some(rec)
    }

    /// Fail a running job: release its slots and record the reason.
    pub fn fail(&mut self, id: JobId, reason: String) {
        if let Some(mut rec) = self.finish(id) {
            self.first_failed_at.remove(&id);
            rec.state = JobState::Failed { reason };
            self.record_terminal(rec);
        }
    }

    /// Append a terminal (Done/Failed) record, enforcing the retention
    /// cap. Every completion path — live cluster, WAL replay, snapshot
    /// restore — funnels through here so the in-memory history and the
    /// HA snapshot stay bounded identically on both sides of a failover.
    pub fn record_terminal(&mut self, rec: JobRecord) {
        self.completed.push(rec);
        self.trim_completed();
    }

    /// Terminal records ever seen (retained + trimmed): the
    /// driver-facing progress counter, immune to the retention cap.
    pub fn completed_total(&self) -> usize {
        self.completed_trimmed as usize + self.completed.len()
    }

    fn trim_completed(&mut self) {
        if self.completed_retention > 0 && self.completed.len() > self.completed_retention {
            let excess = self.completed.len() - self.completed_retention;
            self.completed.drain(..excess);
            self.completed_trimmed += excess as u64;
        }
    }

    /// The autoscaler scaled up at `at`: arm the mark and journal it.
    pub fn note_scale_up(&mut self, at: SimTime) {
        self.last_scale_up = Some(at);
        if let Some(j) = self.journal.as_mut() {
            j.push(crate::ha::wal::WalEvent::ScaleUp { at });
        }
    }

    /// The autoscaler retired at least one node at `at`.
    pub fn note_scale_down(&mut self, at: SimTime) {
        self.last_scale_down = Some(at);
        if let Some(j) = self.journal.as_mut() {
            j.push(crate::ha::wal::WalEvent::ScaleDown { at });
        }
    }

    /// Running jobs whose reserved slice references a host that is no
    /// longer advertised by the (health-gated) hostfile — the recovery
    /// pipeline's per-tick cross-check. Sorted for determinism.
    pub fn lost_jobs(&self) -> Vec<JobId> {
        let advertised: HashSet<Ipv4> = self
            .hostfile()
            .map(|hf| hf.hosts.into_iter().map(|h| h.addr).collect())
            .unwrap_or_default();
        let mut ids: Vec<JobId> = self
            .reserved
            .iter() // lint: sorted
            .filter(|(_, slice)| slice.iter().any(|h| !advertised.contains(&h.addr)))
            .map(|(&id, _)| id)
            .collect();
        ids.sort();
        ids
    }

    /// Running jobs holding slots on `addr` — for immediate failure when
    /// a machine dies under them (mpirun exits long before the TTL).
    pub fn jobs_on_addr(&self, addr: Ipv4) -> Vec<JobId> {
        let mut ids: Vec<JobId> = self
            .reserved
            .iter() // lint: sorted
            .filter(|(_, slice)| slice.iter().any(|h| h.addr == addr))
            .map(|(&id, _)| id)
            .collect();
        ids.sort();
        ids
    }

    /// A dispatched job never actually launched (a host in its slice was
    /// already unreachable): put it back at the head of the queue without
    /// charging its retry budget — no work was started, the failure is
    /// the launcher's, not the job's.
    pub fn unlaunch(&mut self, id: JobId, now: SimTime) {
        if let Some(rec) = self.running.remove(&id) {
            self.reserved.remove(&id);
            self.first_failed_at.entry(id).or_insert(now);
            self.queue.push_front((rec.spec, rec.queued_at));
            self.dirty_queue_view();
            self.log(crate::ha::wal::WalEvent::Unlaunched { at: now, id });
        }
    }

    /// Pure half of [`Head::credited_rerun`]: the rerun kind, the
    /// credited Jacobi steps (`None` for synthetic jobs, which
    /// checkpoint continuously) and the virtual work the rerun must
    /// redo — without mutating any progress bookkeeping. Also powers
    /// the preemption cost model's per-victim waste estimate
    /// ([`Head::preempt_waste`]).
    fn rerun_plan(&self, rec: &JobRecord, now: SimTime) -> (JobKind, Option<usize>, SimTime) {
        let started = match rec.state {
            JobState::Running { started } => started,
            _ => now,
        };
        let elapsed = now.saturating_sub(started);
        match rec.spec.kind.clone() {
            JobKind::Synthetic { duration } => {
                // the elapsed virtual time is credited in full: the rerun
                // only owes the remainder
                let remaining = duration.saturating_sub(elapsed).max(SimTime::from_secs(1));
                (JobKind::Synthetic { duration: remaining }, None, SimTime::ZERO)
            }
            JobKind::Jacobi { px, py, tile, steps } => {
                // credit the steps executed this attempt, prorated by how
                // much of the planned virtual duration elapsed, rounded
                // down to the last completed checkpoint
                let ran = rec.result.map(|(s, _)| s).unwrap_or(0).min(steps);
                let frac = match rec.planned_duration {
                    Some(d) if d > SimTime::ZERO => {
                        (elapsed.as_secs_f64() / d.as_secs_f64()).min(1.0)
                    }
                    _ => 0.0,
                };
                let ckpt = self.checkpoint_every_steps.min(steps.max(1)).max(1);
                // steps the job had virtually performed when it stopped
                let done_virtual = ((ran as f64 * frac) as usize).min(steps);
                let credited = (done_virtual / ckpt * ckpt).min(steps);
                // work past the checkpoint is redone by the rerun
                let rerun_steps = done_virtual.saturating_sub(credited);
                let wasted = match rec.planned_duration {
                    Some(d) if ran > 0 => SimTime::from_secs_f64(
                        d.as_secs_f64() * rerun_steps as f64 / ran as f64,
                    ),
                    _ => SimTime::ZERO,
                };
                let remaining = (steps - credited).max(1);
                (JobKind::Jacobi { px, py, tile, steps: remaining }, Some(credited), wasted)
            }
        }
    }

    /// Virtual work that would be redone if the running job `id` were
    /// stopped at `now` — its distance past the last checkpoint. This is
    /// the preemption cost model's victim-ranking signal: among
    /// equally-low-priority victims the policy preempts the job closest
    /// to a checkpoint (0 for synthetic jobs, which checkpoint
    /// continuously, and for jobs not currently running).
    pub fn preempt_waste(&self, id: JobId, now: SimTime) -> SimTime {
        match self.running.get(&id) {
            Some(rec) => self.rerun_plan(rec, now).2,
            None => SimTime::ZERO,
        }
    }

    /// Compute the rerun spec-kind plus the virtual work the rerun must
    /// redo when `rec` leaves the running pool early, crediting partial
    /// progress: synthetic jobs resume at their remaining duration
    /// (continuous checkpointing, zero waste), Jacobi restarts from the
    /// last completed residual checkpoint. Shared by the fault-requeue
    /// and preemption paths so the two can never drift.
    fn credited_rerun(&mut self, rec: &JobRecord, now: SimTime) -> (JobKind, SimTime) {
        let (kind, credited, wasted) = self.rerun_plan(rec, now);
        if let Some(credited) = credited {
            *self.jacobi_progress.entry(rec.spec.id).or_insert(0) += credited;
        }
        (kind, wasted)
    }

    /// Advance a job's attempt generation (stale-completion guard).
    fn bump_attempt(&mut self, id: JobId) -> u32 {
        let a = self.attempts.entry(id).or_insert(0);
        *a += 1;
        *a
    }

    /// Checkpoint-and-requeue a running job to make room for
    /// higher-priority work. Shares the partial-progress credit path
    /// with [`Head::handle_lost_job`], but does **not** charge the
    /// fault retry budget — preemption is the scheduler's choice, not
    /// a node failure. The attempt generation still advances, so a
    /// completion event scheduled for the preempted run can never
    /// complete the requeued job early. Returns the new attempt
    /// generation and the virtual work the rerun must redo.
    pub fn preempt(&mut self, id: JobId, now: SimTime) -> Option<(u32, SimTime)> {
        // settle the victim's slot-seconds before it leaves the pool —
        // preempted work still charges its tenant's ledger
        self.accrue_usage(now);
        let rec = self.running.remove(&id)?;
        self.reserved.remove(&id);
        let (kind, wasted) = self.credited_rerun(&rec, now);
        let attempt = self.bump_attempt(id);
        let spec = JobSpec { kind, ..rec.spec.clone() };
        self.queue.push_back((spec, rec.queued_at));
        self.dirty_queue_view();
        self.log(crate::ha::wal::WalEvent::Preempted { at: now, id });
        Some((attempt, wasted))
    }

    /// A running job's reservation lost a node (machine death, hang or
    /// partition): release the slots and either requeue the job with
    /// partial-progress credit — synthetic jobs resume at their remaining
    /// duration, Jacobi restarts from the last completed checkpoint — or,
    /// once its retry budget is spent, record it as permanently failed.
    pub fn handle_lost_job(&mut self, id: JobId, now: SimTime, reason: &str) -> LossOutcome {
        if !self.running.contains_key(&id) {
            return LossOutcome::NotRunning;
        }
        if self.journal.is_some() {
            // one event covers both outcomes: replay re-runs the retry
            // budget below against identical state, so requeue-vs-abandon
            // reproduces without being logged
            let ev = crate::ha::wal::WalEvent::Lost {
                at: now,
                id,
                reason: reason.to_string(),
            };
            self.log(ev);
        }
        // settle slot-seconds up to the loss: the doomed attempt's held
        // interval charges its tenant like any other run time
        self.accrue_usage(now);
        let spent = self.retries.get(&id).copied().unwrap_or(0);
        if spent >= self.max_retries {
            // budget spent: the regular fail path already releases the
            // reservation, folds credited progress into the result and
            // records the job as permanently failed
            self.fail(
                id,
                format!("{reason} (retry budget of {} exhausted)", self.max_retries),
            );
            return LossOutcome::Abandoned { id };
        }
        let rec = match self.running.remove(&id) {
            Some(rec) => rec,
            None => return LossOutcome::NotRunning,
        };
        self.reserved.remove(&id);
        self.first_failed_at.entry(id).or_insert(now);
        let (kind, wasted) = self.credited_rerun(&rec, now);
        self.retries.insert(id, spent + 1);
        let attempt = self.bump_attempt(id);
        let spec = JobSpec { kind, ..rec.spec.clone() };
        self.queue.push_front((spec, rec.queued_at));
        self.dirty_queue_view();
        LossOutcome::Requeued { id, attempt, wasted }
    }

    /// Priority- and share-weighted queue demand for the autoscaler.
    ///
    /// Each queued job contributes its width scaled by
    /// [`priority_weight`](crate::cluster::policy::priority_weight)
    /// (urgent backlogs provision harder); the per-tenant sums are then
    /// share-capped by
    /// [`share_weighted_demand`](crate::tenancy::fairshare::share_weighted_demand),
    /// so one tenant flooding the queue cannot force unbounded
    /// scale-up — it is provisioned for at most twice its
    /// weight-proportional share of the aggregate (never below its
    /// widest single job; per-tenant share weights come from the
    /// ledger's `[tenant_weights]` multipliers). With one
    /// active tenant and batch priorities this equals
    /// [`Head::queued_slots`], the pre-tenancy signal. Deferred jobs
    /// contribute nothing.
    pub fn weighted_queued_slots(&self) -> u32 {
        let mut per_tenant: BTreeMap<u64, (f64, u32, f64)> = BTreeMap::new();
        for (j, _) in &self.queue {
            // per-job ceil, exactly as the pre-tenancy signal summed it,
            // so a single-tenant queue reproduces the old figure even
            // for fractional priority weights
            let w = (j.ranks as f64
                * crate::cluster::policy::priority_weight(j.priority))
            .ceil();
            let entry = per_tenant
                .entry(j.tenant)
                .or_insert((0.0, 0, self.ledger.weight(j.tenant)));
            entry.0 += w;
            entry.1 = entry.1.max(j.ranks);
        }
        // A tenant's demand can never exceed its running-slot quota
        // headroom: jobs past the quota dispatch onto slots the tenant
        // itself frees, not onto new capacity — provisioning for them
        // would buy machines the quota guarantees stay idle.
        if self.quotas.max_running_slots != u32::MAX {
            // one pass over the running pool, not one scan per tenant
            let running_by_tenant = self.running_slots_by_tenant();
            for (t, entry) in per_tenant.iter_mut() {
                let headroom = self
                    .quotas
                    .max_running_slots
                    .saturating_sub(running_by_tenant.get(t).copied().unwrap_or(0));
                entry.0 = entry.0.min(headroom as f64);
                entry.1 = entry.1.min(headroom);
            }
        }
        crate::tenancy::fairshare::share_weighted_demand(&per_tenant)
    }

    /// Host addresses in a running job's reserved slice (empty if the
    /// job is not running). The HA takeover validates these against
    /// the live container map before re-arming completions.
    pub(crate) fn reserved_hosts(&self, id: JobId) -> Vec<Ipv4> {
        self.reserved
            .get(&id)
            .map(|slice| slice.iter().map(|h| h.addr).collect())
            .unwrap_or_default()
    }

    /// WAL-replay install of a logged dispatch: move the job out of the
    /// queue onto the logged reservation, bypassing the policy — the
    /// placement decision depended on the historical hostfile, which is
    /// exactly why the slice is in the log. The subsequent `Launched`
    /// entry fills in the planned duration and any launch-time result.
    pub(crate) fn wal_replay_dispatch(
        &mut self,
        id: JobId,
        attempt: u32,
        slice: Vec<HostSlot>,
        at: SimTime,
    ) {
        let Some(pos) = self.queue.iter().position(|(j, _)| j.id == id) else {
            log::warn!("ha replay: dispatch of {id} not in queue, skipping");
            return;
        };
        let Some((spec, queued_at)) = self.queue.remove(pos) else { return };
        self.reserved.insert(id, slice);
        self.running.insert(
            id,
            JobRecord {
                spec,
                state: JobState::Running { started: at },
                result: None,
                queued_at,
                attempt,
                planned_duration: None,
            },
        );
        self.dirty_queue_view();
    }

    /// Export the head's complete dynamic state for an HA snapshot.
    /// Hash maps are emitted sorted so identical state always encodes
    /// byte-identically.
    pub fn dump(&self) -> crate::ha::snapshot::HeadDump {
        fn sorted<K: Ord + Copy, V: Clone>(m: &HashMap<K, V>) -> Vec<(K, V)> {
            let mut v: Vec<(K, V)> = m.iter().map(|(&k, val)| (k, val.clone())).collect(); // lint: sorted
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        }
        let mut running: Vec<JobRecord> = self.running.values().cloned().collect(); // lint: sorted
        running.sort_by_key(|r| r.spec.id);
        let mut deferred = Vec::new();
        for (&tenant, pen) in &self.deferred {
            for (spec, at) in pen {
                deferred.push((tenant, spec.clone(), *at));
            }
        }
        crate::ha::snapshot::HeadDump {
            queue: self.queue.iter().cloned().collect(),
            deferred,
            running,
            completed: self.completed.clone(),
            reserved: sorted(&self.reserved),
            retries: sorted(&self.retries),
            attempts: sorted(&self.attempts),
            jacobi_progress: sorted(&self.jacobi_progress),
            first_failed_at: sorted(&self.first_failed_at),
            last_accrued: self.last_accrued,
            ledger_accounts: self.ledger.export_accounts(),
            completed_trimmed: self.completed_trimmed,
            last_scale_up: self.last_scale_up,
            last_scale_down: self.last_scale_down,
            last_arrival_cursor: self.last_arrival_cursor.clone(),
        }
    }

    /// Install a snapshot produced by [`Head::dump`], replacing all
    /// dynamic state. Config knobs (policy, quotas, intervals, ledger
    /// half-life and weights) are untouched — a standby gets those from
    /// its own deployment configuration.
    pub fn restore(&mut self, d: crate::ha::snapshot::HeadDump) {
        self.queue = d.queue.into_iter().collect();
        self.deferred = BTreeMap::new();
        for (tenant, spec, at) in d.deferred {
            self.deferred.entry(tenant).or_default().push_back((spec, at));
        }
        self.running = d.running.into_iter().map(|r| (r.spec.id, r)).collect();
        self.completed = d.completed;
        self.completed_trimmed = d.completed_trimmed;
        self.trim_completed();
        self.last_scale_up = d.last_scale_up;
        self.last_scale_down = d.last_scale_down;
        self.reserved = d.reserved.into_iter().collect();
        self.retries = d.retries.into_iter().collect();
        self.attempts = d.attempts.into_iter().collect();
        self.jacobi_progress = d.jacobi_progress.into_iter().collect();
        self.first_failed_at = d.first_failed_at.into_iter().collect();
        self.last_accrued = d.last_accrued;
        self.ledger.restore_accounts(&d.ledger_accounts);
        self.last_arrival_cursor = d.last_arrival_cursor;
        self.dirty_queue_view();
    }
}

/// Take `ranks` slots out of `free` (mutating it), filling hosts in
/// hostfile order. `None` if the free pool is too small.
fn carve(free: &mut [HostSlot], ranks: u32) -> Option<Vec<HostSlot>> {
    let total: u32 = free.iter().map(|h| h.slots).sum();
    if total < ranks {
        return None;
    }
    let mut need = ranks;
    let mut take = Vec::new();
    for h in free.iter_mut() {
        if need == 0 {
            break;
        }
        let t = h.slots.min(need);
        if t > 0 {
            take.push(HostSlot { addr: h.addr, slots: t });
            h.slots -= t;
            need -= t;
        }
    }
    Some(take)
}

/// Width-only carve exposed for the policy module's width-vs-topology
/// comparison tests.
#[cfg(test)]
pub(crate) fn carve_for_test(free: &mut [HostSlot], ranks: u32) -> Option<Vec<HostSlot>> {
    carve(free, ranks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::policy::PolicyKind;
    use crate::util::Rng;

    fn job(id: u32, ranks: u32) -> JobSpec {
        jobd(id, ranks, 10)
    }

    fn jobd(id: u32, ranks: u32, secs: u64) -> JobSpec {
        JobSpec {
            id: JobId::new(id),
            name: format!("job{id}"),
            ranks,
            kind: JobKind::Synthetic { duration: SimTime::from_secs(secs) },
            priority: 0,
            tenant: 0,
        }
    }

    fn jobp(id: u32, ranks: u32, secs: u64, priority: i32) -> JobSpec {
        JobSpec { priority, ..jobd(id, ranks, secs) }
    }

    fn jobt(id: u32, ranks: u32, secs: u64, tenant: u64) -> JobSpec {
        JobSpec { tenant, ..jobd(id, ranks, secs) }
    }

    #[test]
    fn completed_history_is_bounded() {
        let mut h = Head::new();
        h.completed_retention = 3;
        for i in 0..5 {
            h.record_terminal(JobRecord {
                spec: job(i, 1),
                state: JobState::Failed { reason: "x".into() },
                result: None,
                queued_at: SimTime::ZERO,
                attempt: 0,
                planned_duration: None,
            });
        }
        assert_eq!(h.completed.len(), 3, "history capped at the retention");
        assert_eq!(h.completed_trimmed, 2);
        assert_eq!(h.completed_total(), 5, "total stays monotonic");
        assert_eq!(h.completed[0].spec.id, JobId::new(2), "oldest dropped first");
        // the trim count and cap survive a dump/restore roundtrip
        let dump = h.dump();
        let mut back = Head::new();
        back.completed_retention = 3;
        back.restore(dump);
        assert_eq!(back.completed_total(), 5);
        assert_eq!(back.completed.len(), 3);
    }

    #[test]
    fn zero_retention_means_unlimited() {
        let mut h = Head::new();
        h.completed_retention = 0;
        for i in 0..50 {
            h.record_terminal(JobRecord {
                spec: job(i, 1),
                state: JobState::Failed { reason: "x".into() },
                result: None,
                queued_at: SimTime::ZERO,
                attempt: 0,
                planned_duration: None,
            });
        }
        assert_eq!(h.completed.len(), 50);
        assert_eq!(h.completed_trimmed, 0);
    }

    #[test]
    fn jobs_wait_for_slots() {
        let mut h = Head::new();
        h.submit(job(0, 16), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_none(), "no hostfile yet");
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        let r = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(r.spec.id, JobId::new(0));
        assert_eq!(r.hostfile_slice.total_slots(), 16);
        assert!(matches!(h.running[&r.spec.id].state, JobState::Running { .. }));
    }

    #[test]
    fn concurrent_jobs_share_the_cluster() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 4), SimTime::ZERO);
        h.submit(job(1, 4), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert_eq!(h.running.len(), 2);
        assert_eq!(h.free_slots(), 16);
        assert!(h.overbooked_hosts().is_empty());
    }

    #[test]
    fn max_concurrent_one_reproduces_serial_head() {
        let mut h = Head::new();
        h.max_concurrent = 1;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 4), SimTime::ZERO);
        h.submit(job(1, 4), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        assert!(h.start_next(SimTime::ZERO).is_none(), "capped at one job");
        h.finish(JobId::new(0));
        assert!(h.start_next(SimTime::ZERO).is_some());
    }

    #[test]
    fn demanded_slots_counts_queue_and_running() {
        let mut h = Head::new();
        h.submit(job(0, 16), SimTime::ZERO);
        h.submit(job(1, 8), SimTime::ZERO);
        assert_eq!(h.demanded_slots(), 24);
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(h.queued_slots(), 8);
        assert_eq!(h.reserved_slots(), 16);
        assert_eq!(h.demanded_slots(), 24);
    }

    /// The seed's `fifo_order_holds` documented head-of-line blocking: a
    /// 1-rank job stuck behind a full-width job. Now the wide job takes
    /// the whole cluster and the narrow one waits only because zero
    /// slots are free — not because of the queue position.
    #[test]
    fn full_width_job_still_blocks_when_no_slots_free() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=32\n".into();
        h.submit(job(0, 32), SimTime::ZERO);
        h.submit(job(1, 1), SimTime::ZERO);
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(0));
        assert!(h.start_next(SimTime::ZERO).is_none(), "no free slots");
        h.finish(JobId::new(0));
        assert_eq!(h.start_next(SimTime::ZERO).unwrap().spec.id, JobId::new(1));
    }

    /// Backfill regression test (was `fifo_order_holds`, which asserted
    /// the bug): a narrow job overtakes a blocked wide job when it fits
    /// into slots the wide job cannot use yet.
    #[test]
    fn backfill_fills_spare_slots_behind_blocked_head() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=16\n10.10.0.3 slots=16\n".into();
        h.submit(job(0, 24), SimTime::ZERO);
        h.submit(job(1, 16), SimTime::ZERO); // head once job0 runs; blocked (8 free)
        h.submit(job(2, 4), SimTime::ZERO); // backfills into the 8 free slots
        let r0 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r0.spec.id, JobId::new(0));
        assert!(!r0.backfilled);
        let r2 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r2.spec.id, JobId::new(2), "narrow job must backfill");
        assert!(r2.backfilled);
        // 4 slots free, head needs 16: nothing else starts
        assert!(h.start_next(SimTime::ZERO).is_none());
        assert_eq!(h.queue.len(), 1);
        assert!(h.overbooked_hosts().is_empty());
    }

    /// Conservative guard: younger jobs may never hold so many slots
    /// that the head-of-queue job's full width cannot be assembled.
    #[test]
    fn backfill_never_overcommits_the_heads_claim() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=16\n10.10.0.3 slots=16\n".into();
        h.submit(job(0, 20), SimTime::ZERO);
        let _ = h.start_next(SimTime::ZERO).unwrap(); // 12 free
        h.submit(job(1, 24), SimTime::ZERO); // head, blocked
        h.submit(job(2, 10), SimTime::ZERO); // fits in 12 free, but 24+10 > 32
        assert!(
            h.start_next(SimTime::ZERO).is_none(),
            "backfill must leave the head job's width claimable"
        );
        h.submit(job(3, 8), SimTime::ZERO); // 24 + 8 <= 32: allowed
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(3));
        assert!(r.backfilled);
    }

    #[test]
    fn reservations_release_on_finish_and_fail() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(job(0, 8), SimTime::ZERO);
        h.submit(job(1, 8), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(h.free_slots(), 4);
        h.fail(JobId::new(0), "boom".into());
        assert_eq!(h.free_slots(), 12);
        assert!(matches!(h.completed[0].state, JobState::Failed { .. }));
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(1));
        h.finish(JobId::new(1));
        assert_eq!(h.free_slots(), 12);
        assert!(h.reserved_addrs().is_empty());
    }

    #[test]
    fn lost_job_requeues_with_remaining_duration() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        h.submit(job(0, 16), SimTime::ZERO);
        let started = h.start_next(SimTime::from_secs(10)).unwrap();
        assert_eq!(started.attempt, 0);
        // node 10.10.0.3 dies 4s into the 10s job
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(14), "node died");
        assert!(
            matches!(out, LossOutcome::Requeued { attempt: 1, .. }),
            "{out:?}"
        );
        assert!(h.running.is_empty());
        assert!(h.reserved_addrs().is_empty(), "slots must be released");
        assert_eq!(h.queue.len(), 1);
        let (spec, _) = h.queue.front().unwrap();
        match &spec.kind {
            JobKind::Synthetic { duration } => {
                assert_eq!(*duration, SimTime::from_secs(6), "elapsed time is credited");
            }
            other => panic!("kind changed: {other:?}"),
        }
        // the rerun carries the bumped attempt number
        let restarted = h.start_next(SimTime::from_secs(20)).unwrap();
        assert_eq!(restarted.attempt, 1);
        assert_eq!(h.first_failed_at[&JobId::new(0)], SimTime::from_secs(14));
    }

    #[test]
    fn retry_budget_exhaustion_abandons_the_job() {
        let mut h = Head::new();
        h.max_retries = 2;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(job(0, 8), SimTime::ZERO);
        for round in 0..3 {
            let s = h.start_next(SimTime::from_secs(round)).unwrap();
            assert_eq!(s.attempt, round as u32);
            let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(round + 1), "boom");
            if round < 2 {
                assert!(matches!(out, LossOutcome::Requeued { .. }), "{out:?}");
            } else {
                assert_eq!(out, LossOutcome::Abandoned { id: JobId::new(0) });
            }
        }
        assert!(h.queue.is_empty());
        assert!(h.running.is_empty());
        assert_eq!(h.completed.len(), 1);
        assert!(matches!(h.completed[0].state, JobState::Failed { .. }));
        // a second report for the same job is a no-op
        assert_eq!(
            h.handle_lost_job(JobId::new(0), SimTime::from_secs(9), "boom"),
            LossOutcome::NotRunning
        );
    }

    #[test]
    fn jacobi_resumes_from_the_last_checkpoint() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(
            JobSpec {
                id: JobId::new(0),
                name: "jac".into(),
                ranks: 16,
                kind: JobKind::Jacobi { px: 4, py: 4, tile: 64, steps: 100 },
                priority: 0,
                tenant: 0,
            },
            SimTime::ZERO,
        );
        h.start_next(SimTime::ZERO).unwrap();
        // the dispatcher ran all 100 steps and planned a 100s duration
        let rec = h.running.get_mut(&JobId::new(0)).unwrap();
        rec.result = Some((100, 0.5));
        rec.planned_duration = Some(SimTime::from_secs(100));
        // the node dies halfway through the virtual duration: 50 steps
        // performed -> rounds down to checkpoint 40
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(50), "died");
        let LossOutcome::Requeued { wasted, .. } = out else {
            panic!("{out:?}");
        };
        assert_eq!(wasted, SimTime::from_secs(10), "50 done - 40 credited = 10s redone");
        let (spec, _) = h.queue.front().unwrap();
        match &spec.kind {
            JobKind::Jacobi { steps, .. } => assert_eq!(*steps, 60, "resume at step 40"),
            other => panic!("kind changed: {other:?}"),
        }
        // on eventual completion the credited steps fold into the result
        h.start_next(SimTime::from_secs(60)).unwrap();
        h.running.get_mut(&JobId::new(0)).unwrap().result = Some((60, 1e-7));
        let done = h.finish(JobId::new(0)).unwrap();
        assert_eq!(done.result, Some((100, 1e-7)));
    }

    #[test]
    fn lost_jobs_cross_checks_reservations_against_the_hostfile() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        h.submit(job(0, 16), SimTime::ZERO); // spans both hosts
        h.submit(job(1, 4), SimTime::ZERO); // fits on the first host
        h.start_next(SimTime::ZERO).unwrap();
        h.start_next(SimTime::ZERO).unwrap();
        assert!(h.lost_jobs().is_empty());
        // the second host drops out of the hostfile (TTL expiry)
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        assert_eq!(h.lost_jobs(), vec![JobId::new(0)]);
        let addr = Ipv4::parse("10.10.0.3").unwrap();
        assert_eq!(h.jobs_on_addr(addr), vec![JobId::new(0)]);
        assert!(h.jobs_on_addr(Ipv4::parse("10.10.0.9").unwrap()).is_empty());
    }

    #[test]
    fn unlaunch_requeues_without_charging_the_budget() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(job(0, 8), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        h.unlaunch(JobId::new(0), SimTime::from_secs(1));
        assert!(h.running.is_empty());
        assert_eq!(h.queue.len(), 1);
        let s = h.start_next(SimTime::from_secs(2)).unwrap();
        assert_eq!(s.attempt, 0, "an aborted launch must not consume a retry");
    }

    /// Property: over random job mixes, (a) no host is ever overbooked,
    /// (b) the queue fully drains (backfill never starves the head), and
    /// (c) every dispatched slice has exactly the job's width.
    #[test]
    fn prop_backfill_is_starvation_free_and_never_double_books() {
        let mut rng = Rng::new(2026);
        for trial in 0..40 {
            let mut h = Head::new();
            // 4 hosts x 12 slots = 48; every job individually fits
            h.hostfile_text =
                "10.0.0.1 slots=12\n10.0.0.2 slots=12\n10.0.0.3 slots=12\n10.0.0.4 slots=12\n"
                    .to_string();
            let total = h.slots_available();
            let n_jobs = 5 + rng.gen_range(15) as u32;
            for i in 0..n_jobs {
                let ranks = 1 + rng.gen_range(total as u64) as u32;
                h.submit(job(i, ranks), SimTime::ZERO);
            }
            let mut started = 0u32;
            let mut steps = 0u32;
            while started < n_jobs {
                steps += 1;
                assert!(steps < 10 * n_jobs + 100, "trial {trial}: scheduler wedged");
                while let Some(s) = h.start_next(SimTime::from_secs(steps as u64)) {
                    assert_eq!(s.hostfile_slice.total_slots(), s.spec.ranks, "trial {trial}");
                    started += 1;
                }
                assert!(h.overbooked_hosts().is_empty(), "trial {trial}: double-booked");
                // complete one random running job so slots churn
                let ids: Vec<JobId> = h.running.keys().copied().collect();
                if let Some(id) = rng.choose(&ids) {
                    h.finish(*id);
                }
            }
            assert!(h.queue.is_empty(), "trial {trial}: queue never drained");
        }
    }

    /// EASY admits a backfill the conservative guard refuses, because
    /// the running jobs' known runtimes prove it finishes before the
    /// blocked head job's reservation.
    #[test]
    fn easy_backfill_uses_known_runtimes() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::easy();
        h.hostfile_text = "10.10.0.2 slots=16\n10.10.0.3 slots=16\n".into();
        h.submit(jobd(0, 20, 100), SimTime::ZERO);
        let _ = h.start_next(SimTime::ZERO).unwrap(); // 12 free until t=100
        h.submit(jobd(1, 24, 60), SimTime::ZERO); // head, blocked
        h.submit(jobd(2, 10, 30), SimTime::ZERO); // 24+10 > 32: fifo refuses
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(2), "EASY must admit the short job");
        assert!(r.backfilled);
        // a job predicted to outlive the reservation (and wider than
        // the head job's spare slots) must wait
        h.submit(jobd(3, 10, 500), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_none());
        assert!(h.overbooked_hosts().is_empty());
    }

    #[test]
    fn priority_policy_dispatches_highest_priority_first() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(jobp(0, 8, 10, 0), SimTime::ZERO);
        h.submit(jobp(1, 8, 10, 3), SimTime::ZERO);
        let r = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r.spec.id, JobId::new(1), "higher priority runs first");
        assert!(!r.backfilled, "the priority head is not a backfill");
    }

    /// A blocked high-priority arrival checkpoints-and-requeues the
    /// lowest-priority running job when that frees enough slots — and
    /// the victim keeps its elapsed-time credit.
    #[test]
    fn preemption_frees_slots_for_high_priority_work() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        h.submit(jobp(0, 24, 100, 0), SimTime::ZERO);
        let first = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(first.spec.id, JobId::new(0));
        h.submit(jobp(1, 24, 30, 5), SimTime::from_secs(40));
        let r = h.start_next(SimTime::from_secs(40)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1), "urgent job must start");
        assert_eq!(r.preempted, vec![JobId::new(0)]);
        assert_eq!(r.preempt_wasted, SimTime::ZERO, "synthetic waste is 0");
        assert!(h.overbooked_hosts().is_empty());
        // the victim is queued with 40s of its 100s credited
        let (spec, _) = h.queue.front().unwrap();
        assert_eq!(spec.id, JobId::new(0));
        match &spec.kind {
            JobKind::Synthetic { duration } => {
                assert_eq!(*duration, SimTime::from_secs(60), "elapsed time credited")
            }
            other => panic!("kind changed: {other:?}"),
        }
        // equal or higher priority running work is never a victim
        h.submit(jobp(2, 24, 10, 5), SimTime::from_secs(41));
        assert!(h.start_next(SimTime::from_secs(41)).is_none());
    }

    /// Preemption advances the attempt generation (so a stale
    /// completion event cannot complete the requeued job) but does not
    /// charge the fault retry budget.
    #[test]
    fn preemption_bumps_attempt_without_charging_retry_budget() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.max_retries = 0; // ANY fault loss abandons immediately
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(jobp(0, 24, 100, 0), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        h.submit(jobp(1, 24, 10, 9), SimTime::from_secs(10));
        let r = h.start_next(SimTime::from_secs(10)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1));
        assert_eq!(r.preempted, vec![JobId::new(0)]);
        h.finish(JobId::new(1));
        // the victim redispatches at generation 1 even though its
        // retry budget (0) is untouched
        let again = h.start_next(SimTime::from_secs(20)).unwrap();
        assert_eq!(again.spec.id, JobId::new(0));
        assert_eq!(again.attempt, 1, "preemption must advance the generation");
        // a real node loss now abandons it (budget 0), proving the
        // preemption above never spent budget
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(21), "died");
        assert_eq!(out, LossOutcome::Abandoned { id: JobId::new(0) });
    }

    /// At the concurrency cap, a preempting policy may still swap
    /// running work: preempt + start keeps the job count constant.
    #[test]
    fn preemption_swaps_work_at_the_concurrency_cap() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.max_concurrent = 1;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(jobp(0, 24, 100, 0), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        h.submit(jobp(1, 24, 10, 5), SimTime::from_secs(10));
        let r = h.start_next(SimTime::from_secs(10)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1), "urgent must swap in at the cap");
        assert_eq!(r.preempted, vec![JobId::new(0)]);
        assert_eq!(h.running.len(), 1, "swap must not exceed the cap");
        // a non-preempting policy at the cap still refuses to start
        let mut serial = Head::new();
        serial.max_concurrent = 1;
        serial.hostfile_text = "10.10.0.2 slots=24\n".into();
        serial.submit(job(0, 4), SimTime::ZERO);
        serial.submit(job(1, 4), SimTime::ZERO);
        assert!(serial.start_next(SimTime::ZERO).is_some());
        assert!(serial.start_next(SimTime::ZERO).is_none());
    }

    #[test]
    fn topo_aware_head_packs_reservations_into_one_rack() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::fifo().with_topo_aware(true);
        h.hostfile_text =
            "10.10.0.2 slots=12\n10.10.0.3 slots=12\n10.10.0.4 slots=12\n".into();
        // hosts .2 -> rack0, .3/.4 -> rack1
        h.rack_of.insert(Ipv4::parse("10.10.0.2").unwrap(), 0);
        h.rack_of.insert(Ipv4::parse("10.10.0.3").unwrap(), 1);
        h.rack_of.insert(Ipv4::parse("10.10.0.4").unwrap(), 1);
        h.submit(job(0, 24), SimTime::ZERO);
        let r = h.start_next(SimTime::ZERO).unwrap();
        let racks: HashSet<usize> = r
            .hostfile_slice
            .hosts
            .iter()
            .map(|s| h.rack_of[&s.addr])
            .collect();
        assert_eq!(racks, HashSet::from([1]), "24 ranks fit rack1 alone: {r:?}");
        assert_eq!(r.hostfile_slice.total_slots(), 24);
        assert!(h.overbooked_hosts().is_empty());
    }

    #[test]
    fn weighted_queued_slots_scales_with_priority() {
        let mut h = Head::new();
        h.submit(jobp(0, 12, 10, 0), SimTime::ZERO);
        assert_eq!(h.weighted_queued_slots(), h.queued_slots());
        h.submit(jobp(1, 12, 10, 2), SimTime::ZERO); // weight 2.0
        assert_eq!(h.queued_slots(), 24);
        assert_eq!(h.weighted_queued_slots(), 12 + 24);
    }

    /// One tenant flooding the queue is provisioned for at most twice
    /// its equal share of the aggregate demand.
    #[test]
    fn weighted_queued_slots_share_caps_a_flooding_tenant() {
        let mut h = Head::new();
        // tenant 1 floods 10 x 24 = 240 slots; tenants 2..=5 queue 8 each
        for i in 0..10 {
            h.submit(jobt(i, 24, 60, 1), SimTime::ZERO);
        }
        for t in 2..=5u64 {
            h.submit(jobt(9 + t as u32, 8, 30, t), SimTime::ZERO);
        }
        assert_eq!(h.queued_slots(), 240 + 32);
        // total 272 over 5 tenants -> cap 108.8: the hog contributes 109
        let weighted = h.weighted_queued_slots();
        assert_eq!(weighted, 109 + 32);
        assert!(weighted < h.queued_slots(), "the flood must be capped");
    }

    /// The Jacobi restart checkpoint is tunable independently of the
    /// residual cadence: a finer interval loses less work on requeue.
    #[test]
    fn checkpoint_interval_is_tunable() {
        let mut h = Head::new();
        h.checkpoint_every_steps = 10;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(
            JobSpec {
                id: JobId::new(0),
                name: "jac".into(),
                ranks: 16,
                kind: JobKind::Jacobi { px: 4, py: 4, tile: 64, steps: 100 },
                priority: 0,
                tenant: 0,
            },
            SimTime::ZERO,
        );
        h.start_next(SimTime::ZERO).unwrap();
        let rec = h.running.get_mut(&JobId::new(0)).unwrap();
        rec.result = Some((100, 0.5));
        rec.planned_duration = Some(SimTime::from_secs(100));
        // died halfway: 50 virtual steps done -> with a 10-step interval
        // the last checkpoint is exactly step 50 (default 20 credits 40)
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(50), "died");
        let LossOutcome::Requeued { wasted, .. } = out else {
            panic!("{out:?}");
        };
        assert_eq!(wasted, SimTime::ZERO, "step 50 is on a 10-step checkpoint");
        let (spec, _) = h.queue.front().unwrap();
        match &spec.kind {
            JobKind::Jacobi { steps, .. } => assert_eq!(*steps, 50, "resume at step 50"),
            other => panic!("kind changed: {other:?}"),
        }
    }

    /// Fair-share dispatch: the tenant with the lower decayed ledger
    /// usage runs first, regardless of submit order.
    #[test]
    fn fairshare_head_orders_by_ledger_usage() {
        let mut h = Head::new();
        h.policy = SchedulePolicy::fairshare();
        h.ledger.charge(1, 1000.0, SimTime::ZERO);
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(jobt(0, 12, 10, 1), SimTime::ZERO); // the hog, submitted first
        h.submit(jobt(1, 12, 10, 2), SimTime::ZERO); // fresh tenant
        let r = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1), "fresh tenant must run first");
        assert!(!r.backfilled, "the fair-share head is not a backfill");
    }

    /// Preemption cost model, end to end through the head: among
    /// equal-priority Jacobi victims the scheduler evicts the one at a
    /// checkpoint, and the wasted-work counter shows the saving vs the
    /// historical lowest-priority/youngest-first choice.
    #[test]
    fn cost_aware_preemption_minimizes_wasted_work() {
        let run = |cost_aware: bool| -> (Vec<JobId>, SimTime) {
            let mut h = Head::new();
            h.policy =
                crate::cluster::policy::SchedulePolicy::priority().with_cost_aware(cost_aware);
            h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
            for id in 0..2u32 {
                h.submit(
                    JobSpec {
                        id: JobId::new(id),
                        name: format!("jac{id}"),
                        ranks: 12,
                        kind: JobKind::Jacobi { px: 3, py: 4, tile: 64, steps: 100 },
                        priority: 0,
                        tenant: 0,
                    },
                    SimTime::ZERO,
                );
            }
            h.start_next(SimTime::ZERO).unwrap();
            h.start_next(SimTime::ZERO).unwrap();
            // job 0 planned 125s: at t=50 it has virtually done 40 steps
            // — exactly checkpoint 40, zero waste if preempted
            let rec = h.running.get_mut(&JobId::new(0)).unwrap();
            rec.result = Some((100, 0.5));
            rec.planned_duration = Some(SimTime::from_secs(125));
            // job 1 planned 100s: at t=50 it has done 50 steps — 10 past
            // checkpoint 40, a 10s rerun if preempted
            let rec = h.running.get_mut(&JobId::new(1)).unwrap();
            rec.result = Some((100, 0.5));
            rec.planned_duration = Some(SimTime::from_secs(100));
            assert_eq!(h.preempt_waste(JobId::new(0), SimTime::from_secs(50)), SimTime::ZERO);
            assert_eq!(
                h.preempt_waste(JobId::new(1), SimTime::from_secs(50)),
                SimTime::from_secs(10)
            );
            h.submit(jobp(2, 12, 10, 5), SimTime::from_secs(50));
            let r = h.start_next(SimTime::from_secs(50)).unwrap();
            assert_eq!(r.spec.id, JobId::new(2), "urgent job must start");
            (r.preempted, r.preempt_wasted)
        };
        let (victims, wasted) = run(true);
        assert_eq!(victims, vec![JobId::new(0)], "cost model picks the checkpointed job");
        assert_eq!(wasted, SimTime::ZERO, "the cheap victim redoes nothing");
        let (victims, wasted) = run(false);
        assert_eq!(victims, vec![JobId::new(1)], "old choice preempts the youngest");
        assert_eq!(wasted, SimTime::from_secs(10), "and pays 10s of redone work");
    }

    /// Weighted shares: a weight-4 tenant's normalized usage is a
    /// quarter of its raw balance, so fair-share runs it ahead of a
    /// lighter-raw-usage unweighted tenant.
    #[test]
    fn fairshare_respects_share_weights() {
        let mut h = Head::new();
        h.policy = SchedulePolicy::fairshare();
        h.ledger.set_weight(1, 4.0);
        h.ledger.charge(1, 1000.0, SimTime::ZERO); // normalized 250
        h.ledger.charge(2, 500.0, SimTime::ZERO); // normalized 500
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.submit(jobt(0, 12, 10, 2), SimTime::ZERO);
        h.submit(jobt(1, 12, 10, 1), SimTime::ZERO);
        let r = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(
            r.spec.id,
            JobId::new(1),
            "the weighted tenant's normalized usage must win"
        );
    }

    /// Weighted shares thread into the autoscaler demand signal: a
    /// weight-2 flooding tenant is provisioned for a 2x slice.
    #[test]
    fn weighted_queued_slots_uses_share_weights() {
        let mut h = Head::new();
        h.ledger.set_weight(1, 2.0);
        for i in 0..10 {
            h.submit(jobt(i, 24, 60, 1), SimTime::ZERO);
        }
        for t in 2..=4u64 {
            h.submit(jobt(9 + t as u32, 8, 30, t), SimTime::ZERO);
        }
        // total 264, Σw = 5: the weight-2 hog's cap is 2·264·2/5 =
        // 211.2 -> 212; the light tenants stay uncapped at 8
        assert_eq!(h.weighted_queued_slots(), 212 + 24);
    }

    /// A tenant at its running-slot quota waits without blocking other
    /// tenants' jobs queued behind it.
    #[test]
    fn running_slot_quota_gates_dispatch_without_blocking_others() {
        let mut h = Head::new();
        h.quotas.max_running_slots = 12;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(jobt(0, 12, 100, 1), SimTime::ZERO);
        h.submit(jobt(1, 12, 100, 1), SimTime::ZERO); // over quota once job0 runs
        h.submit(jobt(2, 12, 100, 2), SimTime::ZERO);
        let r0 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r0.spec.id, JobId::new(0));
        assert_eq!(h.tenant_running_slots(1), 12, "tenant 1 holds its quota");
        let r2 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(
            r2.spec.id,
            JobId::new(2),
            "tenant 2 must not wait behind tenant 1's over-quota job"
        );
        assert_eq!(h.tenant_running_slots(2), 12);
        assert!(h.start_next(SimTime::ZERO).is_none(), "tenant 1 is at quota");
        h.finish(JobId::new(0));
        assert_eq!(h.tenant_running_slots(1), 0, "finish releases the quota");
        let r1 = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(r1.spec.id, JobId::new(1), "freed quota admits the held job");
    }

    /// A 0-job queue cap under Defer could never admit from the pen:
    /// it must degenerate to a recorded rejection, not silent limbo.
    #[test]
    fn zero_queue_cap_under_defer_rejects_instead_of_stranding() {
        let mut h = Head::new();
        h.quotas.max_queued_jobs = 0;
        h.quotas.over_quota = QuotaAction::Defer;
        assert!(matches!(
            h.submit(jobt(0, 8, 10, 1), SimTime::ZERO),
            SubmitOutcome::Rejected { .. }
        ));
        assert_eq!(h.deferred_jobs(), 0, "nothing may be stranded in the pen");
    }

    /// Queued-job quota: Reject hands the spec back; Defer parks the
    /// job and re-admits it once the tenant drains below quota.
    #[test]
    fn queued_job_quota_rejects_or_defers() {
        let mut h = Head::new();
        h.quotas.max_queued_jobs = 1;
        assert!(matches!(h.submit(jobt(0, 8, 10, 1), SimTime::ZERO), SubmitOutcome::Queued));
        match h.submit(jobt(1, 8, 10, 1), SimTime::ZERO) {
            SubmitOutcome::Rejected { spec, reason } => {
                assert_eq!(spec.id, JobId::new(1));
                assert!(reason.contains("quota"), "{reason}");
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // another tenant is unaffected
        assert!(matches!(h.submit(jobt(2, 8, 10, 2), SimTime::ZERO), SubmitOutcome::Queued));

        let mut h = Head::new();
        h.quotas.max_queued_jobs = 1;
        h.quotas.over_quota = QuotaAction::Defer;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        assert!(matches!(h.submit(jobt(0, 8, 10, 1), SimTime::ZERO), SubmitOutcome::Queued));
        assert!(matches!(h.submit(jobt(1, 8, 10, 1), SimTime::ZERO), SubmitOutcome::Deferred));
        assert_eq!(h.deferred_jobs(), 1);
        // dispatching job0 drains the queue; the next dispatch admits
        // and starts the deferred job
        let r0 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r0.spec.id, JobId::new(0));
        let r1 = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(r1.spec.id, JobId::new(1), "deferred job must be admitted");
        assert_eq!(h.deferred_jobs(), 0);
    }

    /// A fresh submission must not grab a just-freed queue slot ahead
    /// of earlier deferred jobs: the pen stays FIFO against new work.
    #[test]
    fn defer_pen_keeps_fifo_against_fresh_submissions() {
        let mut h = Head::new();
        h.quotas.max_queued_jobs = 1;
        h.quotas.over_quota = QuotaAction::Defer;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(jobt(0, 8, 10, 1), SimTime::ZERO);
        assert!(matches!(h.submit(jobt(1, 8, 10, 1), SimTime::ZERO), SubmitOutcome::Deferred));
        let r0 = h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(r0.spec.id, JobId::new(0));
        // the queue is empty but the pen is not: a fresh submission
        // must line up behind the earlier deferred job
        assert!(matches!(h.submit(jobt(2, 8, 10, 1), SimTime::ZERO), SubmitOutcome::Deferred));
        let r1 = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(r1.spec.id, JobId::new(1), "the pen head must run first");
        let r2 = h.start_next(SimTime::from_secs(2)).unwrap();
        assert_eq!(r2.spec.id, JobId::new(2));
    }

    /// Demand the quota guarantees can never be served must not reach
    /// the autoscaler, and a job wider than the running-slot quota is
    /// rejected at submit (it could never dispatch).
    #[test]
    fn running_slot_quota_caps_demand_and_rejects_impossible_widths() {
        let mut h = Head::new();
        h.quotas.max_running_slots = 12;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        // wider than the quota: rejected up front
        assert!(matches!(
            h.submit(jobt(0, 16, 10, 1), SimTime::ZERO),
            SubmitOutcome::Rejected { .. }
        ));
        // five queued 12-rank jobs, none running: demand is the quota
        // headroom (12), not the raw 60
        for i in 1..=5u32 {
            h.submit(jobt(i, 12, 60, 1), SimTime::ZERO);
        }
        assert_eq!(h.queued_slots(), 60);
        assert_eq!(h.weighted_queued_slots(), 12, "demand capped at quota headroom");
        // once one runs the headroom is zero: the rest dispatch onto
        // slots the tenant itself frees, so no new capacity is demanded
        h.start_next(SimTime::ZERO).unwrap();
        assert_eq!(h.weighted_queued_slots(), 0);
    }

    /// Requeue paths preserve tenant attribution and the lost attempt's
    /// held slot-seconds are settled into the right ledger account.
    #[test]
    fn usage_accrues_to_the_running_tenant_across_requeues() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(jobt(0, 8, 100, 3), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(50), "died");
        assert!(matches!(out, LossOutcome::Requeued { .. }), "{out:?}");
        let (spec, _) = h.queue.front().unwrap();
        assert_eq!(spec.tenant, 3, "requeue must keep the tenant");
        let usage = h.ledger.usage_at(3, SimTime::from_secs(50));
        assert!(
            (usage - 400.0).abs() < 1e-6,
            "8 slots x 50s must charge tenant 3: {usage}"
        );
    }

    /// Meta-test for the queue-view cache suite: a deliberately stale
    /// cache must visibly change scheduling. If this stops failing-on-
    /// stale (i.e. `start_next` dispatches anyway), every invalidation
    /// test below loses its teeth — a missed `dirty_queue_view` call
    /// would become unobservable.
    #[test]
    fn stale_queue_view_injection_visibly_breaks_scheduling() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        // build a valid (empty) cached view, then sneak a job in and
        // stamp the stale cache clean again
        assert!(h.start_next(SimTime::ZERO).is_none());
        h.submit(job(0, 4), SimTime::ZERO);
        assert!(!h.queue_view_cache_valid(), "submit must dirty the view");
        h.force_queue_view_clean(SimTime::ZERO);
        assert!(
            h.start_next(SimTime::ZERO).is_none(),
            "a stale empty view must hide the startable job — otherwise \
             the invalidation tests cannot detect missed dirty calls"
        );
        // without the injection the same state dispatches immediately
        h.dirty_queue_view();
        assert!(h.start_next(SimTime::ZERO).is_some());
    }

    /// Preemption mutates the queue (victim requeued) mid-dispatch:
    /// the cache must be invalidated so the re-decide loop and the next
    /// tick see the victim.
    #[test]
    fn preemption_dirties_the_queue_view_cache() {
        let mut h = Head::new();
        h.policy = crate::cluster::policy::SchedulePolicy::priority();
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        h.submit(jobp(0, 24, 100, 0), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        h.submit(jobp(1, 24, 30, 5), SimTime::from_secs(10));
        let r = h.start_next(SimTime::from_secs(10)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1));
        assert_eq!(r.preempted, vec![JobId::new(0)]);
        assert!(
            !h.queue_view_cache_valid(),
            "the requeued victim must invalidate the cached view"
        );
        // and the victim is actually schedulable again once slots free
        h.finish(JobId::new(1));
        assert_eq!(h.start_next(SimTime::from_secs(50)).unwrap().spec.id, JobId::new(0));
    }

    /// Quota re-admission from the deferral pen changes queue
    /// membership: `admit_deferred` must dirty the cache.
    #[test]
    fn quota_readmission_dirties_the_queue_view_cache() {
        let mut h = Head::new();
        h.quotas.max_queued_jobs = 1;
        h.quotas.over_quota = QuotaAction::Defer;
        h.hostfile_text = "10.10.0.2 slots=24\n".into();
        assert!(matches!(h.submit(jobt(0, 8, 10, 1), SimTime::ZERO), SubmitOutcome::Queued));
        assert!(matches!(h.submit(jobt(1, 8, 10, 1), SimTime::ZERO), SubmitOutcome::Deferred));
        // dispatch job 0: the queue drains below quota, so the next
        // start_next admits job 1 from the pen and must rebuild the view
        assert_eq!(h.start_next(SimTime::ZERO).unwrap().spec.id, JobId::new(0));
        assert_eq!(h.deferred_jobs(), 1);
        let r = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(r.spec.id, JobId::new(1), "re-admitted job must be visible");
        assert_eq!(h.deferred_jobs(), 0);
    }

    /// A weighted-share change moves only the ledger version — no
    /// structural invalidation — so the cached view's usage figures
    /// must refresh in place. If the tier-2 refresh were skipped, the
    /// stale usage order would dispatch the wrong tenant.
    #[test]
    fn weight_change_refreshes_cached_usage_for_fairshare() {
        let mut h = Head::new();
        h.policy = SchedulePolicy::fairshare();
        // neither 24-rank job fits one 12-slot host: the first dispatch
        // attempt decides Wait, leaving a valid cached view behind
        h.hostfile_text = "10.10.0.2 slots=12\n".into();
        h.ledger.charge(1, 1000.0, SimTime::ZERO);
        h.ledger.charge(2, 400.0, SimTime::ZERO);
        h.submit(jobt(0, 24, 10, 1), SimTime::ZERO);
        h.submit(jobt(1, 24, 10, 2), SimTime::ZERO);
        assert!(h.start_next(SimTime::from_secs(1)).is_none(), "no room yet");
        assert!(h.queue_view_cache_valid());
        // weight 4 quarters tenant 1's normalized usage (250 < 400);
        // only the ledger version moved, the skeleton stays cached
        h.ledger.set_weight(1, 4.0);
        assert!(h.queue_view_cache_valid(), "weight change is not structural");
        // capacity arrives (the hostfile is read fresh, not cached)
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        let r = h.start_next(SimTime::from_secs(1)).unwrap();
        assert_eq!(
            r.spec.id,
            JobId::new(0),
            "the in-place usage refresh must apply the new weights"
        );
    }

    /// A fault requeue (push_front) changes queue order: the cache must
    /// be dirtied so the requeued job is dispatched next, not the
    /// stale head.
    #[test]
    fn fault_requeue_dirties_the_queue_view_cache() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        h.submit(job(0, 16), SimTime::ZERO);
        h.submit(job(1, 16), SimTime::ZERO);
        h.start_next(SimTime::ZERO).unwrap();
        // an idle attempt (job 1 cannot fit in the 8 free slots)
        // rebuilds the cache, so the loss below is what invalidates it
        assert!(h.start_next(SimTime::ZERO).is_none());
        assert!(h.queue_view_cache_valid());
        let out = h.handle_lost_job(JobId::new(0), SimTime::from_secs(4), "node died");
        assert!(matches!(out, LossOutcome::Requeued { .. }), "{out:?}");
        assert!(
            !h.queue_view_cache_valid(),
            "fault requeue must invalidate the cached view"
        );
        let r = h.start_next(SimTime::from_secs(5)).unwrap();
        assert_eq!(r.spec.id, JobId::new(0), "requeued job goes to the head");
        assert_eq!(r.attempt, 1);
    }

    /// Steady state: two dispatch attempts against unchanged structure
    /// at the same tick keep the cache valid (the whole point of the
    /// memoization), while a plain submit invalidates it.
    #[test]
    fn queue_view_cache_survives_idle_redecisions() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=4\n".into();
        h.submit(job(0, 4), SimTime::ZERO);
        h.submit(job(1, 4), SimTime::ZERO);
        assert!(h.start_next(SimTime::ZERO).is_some());
        // job 1 cannot fit: the decide ran and cached the view
        assert!(h.start_next(SimTime::ZERO).is_none());
        assert!(h.queue_view_cache_valid());
        // a second no-op attempt leaves it valid (tier-1 reuse)
        assert!(h.start_next(SimTime::ZERO).is_none());
        assert!(h.queue_view_cache_valid());
        h.submit(job(2, 1), SimTime::from_secs(1));
        assert!(!h.queue_view_cache_valid(), "submit must dirty the view");
    }
}
