//! Shared job-trace driver: submit a trace of jobs to a fresh cluster
//! and measure queue waits, overlap, rack spread and makespan. Used by
//! the `vhpc mix` subcommand, `examples/job_mix.rs` and the
//! `ext_autoscale` / `ext_policy` benches so the scenarios never drift
//! apart. [`run_policy_trace`] is the general driver (per-job
//! priorities, any [`SchedulePolicy`]); [`run_job_trace`] keeps the
//! historical `(ranks, duration)` shape on the default FIFO policy;
//! [`run_tenant_trace`] drives an *open-loop* multi-tenant arrival
//! stream (`tenancy::arrivals`) instead of a fixed burst — the harness
//! behind `vhpc tenants` and `benches/ext_tenancy.rs`.

use crate::cluster::head::{JobKind, JobState};
use crate::cluster::metrics::{Histogram, TenantBreakdown};
use crate::cluster::policy::SchedulePolicy;
use crate::cluster::vcluster::VirtualCluster;
use crate::config::ClusterSpec;
use crate::faults::FaultPlan;
use crate::sim::SimTime;
use crate::tenancy::arrivals::{
    stream_fingerprint, tenant_counts, ArrivalGen, JobArrival, PopulationSpec,
};
use crate::tenancy::ledger::TenantQuotas;
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;

/// One job request in a policy trace.
#[derive(Debug, Clone, Copy)]
pub struct JobReq {
    /// MPI slots the job reserves.
    pub ranks: u32,
    /// Synthetic virtual duration, seconds.
    pub secs: u64,
    /// Scheduling priority (0 = batch; only the priority policy orders
    /// by it, but every policy reports it to the autoscaler).
    pub priority: i32,
}

/// What a trace run measured.
#[derive(Debug, Clone)]
pub struct TraceOutcome {
    /// Mean submit-to-start wait across the trace, seconds.
    pub mean_wait: f64,
    /// Worst submit-to-start wait, seconds.
    pub max_wait: f64,
    /// Submit-burst to last-completion span, seconds.
    pub makespan: f64,
    /// Most jobs ever observed running at once.
    pub peak_concurrency: usize,
    /// Jobs that overtook a blocked head-of-queue job.
    pub backfill_starts: u64,
    /// Jobs requeued after losing a node (0 on a fault-free run; the
    /// chaos scenarios drive this through `faults::run_chaos_trace`).
    pub requeues: u64,
    /// Jobs checkpointed-and-requeued to seat higher-priority work
    /// (nonzero only under the priority policy with preemption).
    pub preemptions: u64,
    /// Mean number of racks a job's reservation spanned (1.0 = every
    /// job fully packed into a single rack).
    pub mean_rack_spread: f64,
}

/// The 8-machine cluster the mix scenarios run on: 3 warm nodes, up to
/// 7 compute nodes, fast scaling intervals. Shared by the bench, the
/// example and the `vhpc mix` default so the scenarios stay comparable.
pub fn mix_spec(boot: SimTime) -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = 8;
    spec.machine_spec.boot_time = boot;
    spec.autoscale.min_nodes = 3;
    spec.autoscale.max_nodes = 7;
    spec.autoscale.interval = SimTime::from_secs(5);
    spec.autoscale.cooldown = SimTime::from_secs(10);
    spec.autoscale.idle_timeout = SimTime::from_secs(120);
    spec
}

/// The canonical bursty mix: `wide`-rank jobs bracket a stream of
/// narrow ones — the shape that serialized the seed's one-job head.
/// The 10-entry pattern repeats for `n_jobs` entries, so the bench, the
/// example and `vhpc mix` all measure the same workload shape.
pub fn bursty_trace(wide: u32, n_jobs: usize) -> Vec<(u32, u64)> {
    let pattern: [(u32, u64); 10] = [
        (wide, 60),
        (4, 30),
        (4, 30),
        (12, 45),
        (2, 20),
        (8, 40),
        (1, 15),
        (12, 45),
        (4, 25),
        (wide, 60),
    ];
    (0..n_jobs).map(|i| pattern[i % pattern.len()]).collect()
}

/// The bursty mix as [`JobReq`]s with a sprinkling of urgent work:
/// every fourth job runs at priority 2, the rest at batch priority.
/// Under FIFO/EASY the priorities only weight the autoscaler's demand
/// signal; under the priority policy the urgent jobs jump the queue.
pub fn prioritized_trace(wide: u32, n_jobs: usize) -> Vec<JobReq> {
    bursty_trace(wide, n_jobs)
        .into_iter()
        .enumerate()
        .map(|(i, (ranks, secs))| JobReq {
            ranks,
            secs,
            priority: if i % 4 == 3 { 2 } else { 0 },
        })
        .collect()
}

/// Drive a `(ranks, duration_secs)` trace through a fresh cluster on
/// the default FIFO policy — the historical driver shape, kept so the
/// pre-policy benches reproduce byte for byte. See [`run_policy_trace`].
pub fn run_job_trace(
    spec: ClusterSpec,
    trace: &[(u32, u64)],
    max_concurrent: usize,
    warmup_slots: u32,
    deadline_secs: u64,
) -> Result<(TraceOutcome, VirtualCluster)> {
    let jobs: Vec<JobReq> = trace
        .iter()
        .map(|&(ranks, secs)| JobReq { ranks, secs, priority: 0 })
        .collect();
    run_policy_trace(
        spec,
        &jobs,
        SchedulePolicy::default(),
        max_concurrent,
        warmup_slots,
        deadline_secs,
    )
}

/// Drive `jobs` (all submitted in one burst) through a fresh cluster
/// built from `spec`, scheduling under `policy`. `max_concurrent = 1`
/// reproduces the seed's serial head. Waits for `warmup_slots`
/// advertised slots before submitting; errors if any hostfile slot is
/// ever double-booked or the trace has not drained after
/// `deadline_secs` of virtual time. Returns the outcome plus the
/// cluster for further inspection (metrics, completed records).
pub fn run_policy_trace(
    spec: ClusterSpec,
    jobs: &[JobReq],
    policy: SchedulePolicy,
    max_concurrent: usize,
    warmup_slots: u32,
    deadline_secs: u64,
) -> Result<(TraceOutcome, VirtualCluster)> {
    let trace = jobs;
    let mut vc = VirtualCluster::new(spec)?;
    vc.state.head.max_concurrent = max_concurrent;
    vc.state.head.policy = policy;
    vc.start();
    ensure!(
        vc.advance_until(SimTime::from_secs(600), |st| {
            st.head.slots_available() >= warmup_slots
        }),
        "cluster never advertised {warmup_slots} slots"
    );
    for (i, j) in trace.iter().enumerate() {
        vc.submit_with_priority(
            &format!("mix-{i}"),
            j.ranks,
            JobKind::Synthetic { duration: SimTime::from_secs(j.secs) },
            j.priority,
        );
    }
    let t0 = vc.now();
    let deadline = t0 + SimTime::from_secs(deadline_secs);
    while vc.now() < deadline && vc.completed_total() < trace.len() {
        vc.advance(SimTime::from_secs(1));
        let overbooked = vc.state.head.overbooked_hosts();
        ensure!(overbooked.is_empty(), "double-booked hosts: {overbooked:?}");
    }
    // the scheduler records running-pool depth at every launch, where
    // the true peak is always attained — exact, unlike time sampling
    let peak = vc
        .metrics()
        .histogram("concurrent_jobs")
        .map(|h| h.max() as usize)
        .unwrap_or(0);
    ensure!(
        vc.completed_total() == trace.len(),
        "trace never drained: {}/{} jobs done after {deadline_secs}s",
        vc.completed_total(),
        trace.len()
    );
    let mut waits = Vec::with_capacity(trace.len());
    let mut last_finish = SimTime::ZERO;
    for rec in vc.completed_jobs() {
        match rec.state {
            JobState::Done { started, finished } => {
                waits.push(started.saturating_sub(rec.queued_at).as_secs_f64());
                last_finish = last_finish.max(finished);
            }
            ref other => return Err(anyhow!("job {} not done: {other:?}", rec.spec.name)),
        }
    }
    let outcome = TraceOutcome {
        peak_concurrency: peak,
        mean_wait: waits.iter().sum::<f64>() / waits.len().max(1) as f64,
        max_wait: waits.iter().cloned().fold(0.0, f64::max),
        makespan: last_finish.saturating_sub(t0).as_secs_f64(),
        backfill_starts: vc.metrics().counter("backfill_starts"),
        requeues: vc.metrics().counter("jobs_requeued"),
        preemptions: vc.metrics().counter("jobs_preempted"),
        mean_rack_spread: vc
            .metrics()
            .histogram("job_rack_spread")
            .map(|h| h.mean())
            .unwrap_or(0.0),
    };
    Ok((outcome, vc))
}

/// What an open-loop multi-tenant run measured.
#[derive(Debug, Clone)]
pub struct TenantTraceOutcome {
    /// Arrivals submitted over the window (queued + deferred + quota-
    /// rejected — every submission is accounted for by the drain).
    pub jobs_submitted: usize,
    /// Jobs that reached `Done`.
    pub jobs_completed: usize,
    /// Jobs recorded `Failed` (quota rejections; width rejections).
    pub jobs_failed: usize,
    /// Submissions parked by the queued-job quota (they still complete
    /// later and count in `jobs_completed`).
    pub jobs_deferred: u64,
    /// Distinct tenants that submitted at least one job.
    pub tenants_seen: usize,
    /// Mean / p99 submit-to-start wait over completed jobs, seconds.
    pub mean_wait: f64,
    pub p99_wait: f64,
    /// Mean bounded slowdown ((wait + run) / max(run, 1s)) over jobs.
    pub mean_slowdown: f64,
    /// Jain's fairness index over per-tenant mean waits.
    pub fairness_wait: f64,
    /// Jain's fairness index over per-tenant mean slowdowns — the
    /// headline fairness figure the policy comparison ranks by.
    pub fairness_slowdown: f64,
    /// Per-tenant slowdown distributions (tenant-id order).
    pub slowdown_by_tenant: TenantBreakdown,
    /// First-submit to last-completion span, seconds.
    pub makespan: f64,
    /// Order-sensitive fingerprint of the synthesized arrival stream.
    pub arrivals_fingerprint: u64,
    /// Stable counter snapshot — two same-seed runs must be identical.
    pub fingerprint: BTreeMap<String, u64>,
}

/// Drive an open-loop multi-tenant arrival stream through a fresh
/// cluster for `duration_secs` of virtual time (submissions stop
/// there), then drain. Unlike the burst drivers above, this is the
/// harness that exercises scheduler, autoscaler and ledger under
/// *sustained* load: arrivals keep coming while earlier jobs run, the
/// diurnal swing forces scale-up and scale-down in one run, and
/// campaign bursts stress per-tenant fairness. Errors if any
/// submission is unaccounted for after `deadline_secs`.
pub fn run_tenant_trace(
    spec: ClusterSpec,
    pop: PopulationSpec,
    policy: SchedulePolicy,
    quotas: TenantQuotas,
    duration_secs: u64,
    deadline_secs: u64,
) -> Result<(TenantTraceOutcome, VirtualCluster)> {
    let mut vc = VirtualCluster::new(spec)?;
    vc.state.head.policy = policy;
    vc.state.head.quotas = quotas;
    vc.start();
    ensure!(
        vc.advance_until(SimTime::from_secs(600), |st| st.head.slots_available() > 0),
        "cluster never advertised a slot"
    );
    let max_ranks = vc.state.spec.max_advertisable_slots().max(1);
    let mut gen = ArrivalGen::new(pop);
    let t0 = vc.now();
    let horizon = SimTime::from_secs(duration_secs);
    let mut next = gen.next();
    let mut arrivals: Vec<JobArrival> = Vec::new();
    while vc.now().saturating_sub(t0) < horizon {
        // submit everything due by now (arrival offsets anchor at t0)
        while next.at <= vc.now().saturating_sub(t0) {
            vc.submit_job(
                &format!("t{}-j{}", next.tenant, arrivals.len()),
                next.ranks.min(max_ranks),
                JobKind::Synthetic { duration: next.duration },
                next.priority,
                next.tenant,
            );
            arrivals.push(next);
            next = gen.next();
        }
        vc.advance(SimTime::from_secs(1));
        let overbooked = vc.state.head.overbooked_hosts();
        ensure!(overbooked.is_empty(), "double-booked hosts: {overbooked:?}");
    }
    drain_and_measure(vc, arrivals, t0, deadline_secs)
}

/// Shared tail of the tenant drivers: wait out the drain, then fold the
/// completed records into a [`TenantTraceOutcome`].
fn drain_and_measure(
    mut vc: VirtualCluster,
    arrivals: Vec<JobArrival>,
    t0: SimTime,
    deadline_secs: u64,
) -> Result<(TenantTraceOutcome, VirtualCluster)> {
    let submitted = arrivals.len();
    let deadline = t0 + SimTime::from_secs(deadline_secs);
    while vc.now() < deadline && vc.completed_total() < submitted {
        vc.advance(SimTime::from_secs(1));
    }
    ensure!(
        vc.completed_total() == submitted,
        "tenant trace never drained: {}/{} jobs accounted for after {deadline_secs}s",
        vc.completed_total(),
        submitted
    );

    let mut wait_by_tenant = TenantBreakdown::default();
    let mut slowdown_by_tenant = TenantBreakdown::default();
    let mut waits = Histogram::default();
    let mut slowdowns = Histogram::default();
    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut last_finish = SimTime::ZERO;
    for rec in vc.completed_jobs() {
        match rec.state {
            JobState::Done { started, finished } => {
                completed += 1;
                last_finish = last_finish.max(finished);
                let wait = started.saturating_sub(rec.queued_at).as_secs_f64();
                let run = finished.saturating_sub(started).as_secs_f64().max(1.0);
                let slowdown =
                    (finished.saturating_sub(rec.queued_at).as_secs_f64() / run).max(1.0);
                waits.record(wait);
                slowdowns.record(slowdown);
                wait_by_tenant.observe(rec.spec.tenant, wait);
                slowdown_by_tenant.observe(rec.spec.tenant, slowdown);
            }
            JobState::Failed { .. } => failed += 1,
            ref other => return Err(anyhow!("job {} not done: {other:?}", rec.spec.name)),
        }
    }
    let tenants_seen = tenant_counts(&arrivals).len();
    let outcome = TenantTraceOutcome {
        jobs_submitted: submitted,
        jobs_completed: completed,
        jobs_failed: failed,
        jobs_deferred: vc.metrics().counter("jobs_deferred_quota"),
        tenants_seen,
        mean_wait: waits.mean(),
        p99_wait: waits.percentile(99.0),
        mean_slowdown: slowdowns.mean(),
        fairness_wait: wait_by_tenant.fairness(),
        fairness_slowdown: slowdown_by_tenant.fairness(),
        slowdown_by_tenant,
        makespan: last_finish.saturating_sub(t0).as_secs_f64(),
        arrivals_fingerprint: stream_fingerprint(&arrivals),
        fingerprint: vc.metrics().counters_snapshot(),
    };
    Ok((outcome, vc))
}

/// [`run_tenant_trace`] on an HA-enabled cluster, optionally crashing
/// the head `crash_at` after warm-up. The arrival generator lives on
/// the head: its resume cursor is journaled into the replicated WAL
/// after every pull, pulls stop while the head is down, and after the
/// takeover the stream continues from the cursor the standby replayed —
/// so the synthesized arrival sequence is byte-identical to a
/// crash-free run (`arrivals_fingerprint` matches) and no submission is
/// lost. This is the harness behind `vhpc tenants --crash-at`.
pub fn run_tenant_trace_ha(
    mut spec: ClusterSpec,
    pop: PopulationSpec,
    policy: SchedulePolicy,
    quotas: TenantQuotas,
    duration_secs: u64,
    crash_at: Option<SimTime>,
    deadline_secs: u64,
) -> Result<(TenantTraceOutcome, VirtualCluster)> {
    spec.ha.enabled = true;
    let mut vc = VirtualCluster::new(spec)?;
    vc.state.head.policy = policy;
    vc.state.head.quotas = quotas;
    vc.start();
    ensure!(
        vc.advance_until(SimTime::from_secs(600), |st| st.head.slots_available() > 0),
        "cluster never advertised a slot"
    );
    let max_ranks = vc.state.spec.max_advertisable_slots().max(1);
    let mut gen = ArrivalGen::new(pop);
    let t0 = vc.now();
    if let Some(at) = crash_at {
        vc.inject_faults(&FaultPlan::head_crash(at));
    }
    let horizon = SimTime::from_secs(duration_secs);
    let mut epoch = vc.state.ha.epoch;
    // the stream's start position, so a crash before the first arrival
    // still leaves the standby a valid resume point
    vc.journal_arrival_cursor(gen.cursor());
    let mut next = gen.next();
    let mut arrivals: Vec<JobArrival> = Vec::new();
    while vc.now().saturating_sub(t0) < horizon {
        if vc.state.ha.epoch != epoch {
            // the head died and took the in-memory generator with it:
            // resume from the cursor the takeover replayed. The
            // lookahead arrival held above was never submitted, and the
            // cursor predates its draw, so the restored generator
            // re-emits it first — nothing skips, nothing duplicates.
            epoch = vc.state.ha.epoch;
            let cursor = vc
                .arrival_cursor()
                .ok_or_else(|| anyhow!("takeover did not replay an arrival cursor"))?
                .to_string();
            gen = ArrivalGen::restore(pop, &cursor).map_err(|e| anyhow!("arrival cursor: {e}"))?;
            next = gen.next();
        }
        if !vc.state.ha.head_down() {
            // submit everything due by now; overdue arrivals that piled
            // up during an outage land here in one catch-up batch, at
            // their original offsets
            let mut batch_cursor = None;
            while next.at <= vc.now().saturating_sub(t0) {
                vc.submit_job(
                    &format!("t{}-j{}", next.tenant, arrivals.len()),
                    next.ranks.min(max_ranks),
                    JobKind::Synthetic { duration: next.duration },
                    next.priority,
                    next.tenant,
                );
                arrivals.push(next);
                // captured before the next draw: the position right
                // after the last *submitted* arrival
                batch_cursor = Some(gen.cursor());
                next = gen.next();
            }
            if let Some(cursor) = batch_cursor {
                vc.journal_arrival_cursor(cursor);
            }
        }
        vc.advance(SimTime::from_secs(1));
        let overbooked = vc.state.head.overbooked_hosts();
        ensure!(overbooked.is_empty(), "double-booked hosts: {overbooked:?}");
    }
    // an outage that straddles the horizon must not swallow the tail of
    // the stream: wait out the takeover, then submit whatever was due
    // before the submission window closed (the last in-window pull ran
    // at offset horizon - 1s, same as the crash-free driver)
    if vc.state.ha.head_down() || vc.state.ha.epoch != epoch {
        let wait_deadline = vc.now() + SimTime::from_secs(600);
        while vc.state.ha.head_down() && vc.now() < wait_deadline {
            vc.advance(SimTime::from_secs(1));
        }
        ensure!(!vc.state.ha.head_down(), "standby never took over after the head crash");
        if vc.state.ha.epoch != epoch {
            let cursor = vc
                .arrival_cursor()
                .ok_or_else(|| anyhow!("takeover did not replay an arrival cursor"))?
                .to_string();
            gen = ArrivalGen::restore(pop, &cursor).map_err(|e| anyhow!("arrival cursor: {e}"))?;
            next = gen.next();
            let last_pull = horizon.saturating_sub(SimTime::from_secs(1));
            while next.at <= last_pull {
                vc.submit_job(
                    &format!("t{}-j{}", next.tenant, arrivals.len()),
                    next.ranks.min(max_ranks),
                    JobKind::Synthetic { duration: next.duration },
                    next.priority,
                    next.tenant,
                );
                arrivals.push(next);
                vc.journal_arrival_cursor(gen.cursor());
                next = gen.next();
            }
        }
    }
    drain_and_measure(vc, arrivals, t0, deadline_secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ClusterSpec {
        let mut spec = ClusterSpec::paper_testbed();
        spec.machine_spec.boot_time = SimTime::from_secs(5);
        spec
    }

    #[test]
    fn policy_trace_runs_urgent_work_first_and_reports_rack_spread() {
        let jobs = [
            JobReq { ranks: 24, secs: 20, priority: 0 },
            JobReq { ranks: 24, secs: 20, priority: 0 },
            JobReq { ranks: 8, secs: 10, priority: 3 },
        ];
        let (o, vc) =
            run_policy_trace(spec(), &jobs, SchedulePolicy::priority(), usize::MAX, 24, 600)
                .unwrap();
        assert_eq!(o.preemptions, 0, "burst submit needs no preemption");
        // the paper testbed is a single rack: every slice spans exactly 1
        assert!((o.mean_rack_spread - 1.0).abs() < 1e-9, "{}", o.mean_rack_spread);
        // the priority head ran before the batch wall submitted ahead of it
        assert_eq!(vc.completed_jobs()[0].spec.priority, 3);
    }

    #[test]
    fn tenant_trace_drains_and_reports_fairness() {
        let mut pop = PopulationSpec::new(10, 7);
        pop.rate_per_sec = 0.05;
        pop.campaign_prob = 0.1;
        let (o, vc) = run_tenant_trace(
            spec(),
            pop,
            SchedulePolicy::fairshare(),
            TenantQuotas::default(),
            300,
            3600,
        )
        .unwrap();
        assert!(o.jobs_submitted > 0, "300s at 0.05/s must submit work");
        assert_eq!(o.jobs_completed + o.jobs_failed, o.jobs_submitted);
        assert!(o.fairness_slowdown > 0.0 && o.fairness_slowdown <= 1.0 + 1e-9);
        assert!(o.fairness_wait > 0.0 && o.fairness_wait <= 1.0 + 1e-9);
        assert!((1..=10).contains(&o.tenants_seen));
        assert!(o.mean_slowdown >= 1.0);
        assert!(vc.state.head.overbooked_hosts().is_empty());
    }

    #[test]
    fn tenant_stream_survives_a_head_crash_byte_identically() {
        let mut pop = PopulationSpec::new(8, 21);
        pop.rate_per_sec = 0.08;
        pop.campaign_prob = 0.3; // crash is likely to land mid-campaign
        let run = |crash: Option<SimTime>| {
            run_tenant_trace_ha(
                spec(),
                pop,
                SchedulePolicy::fairshare(),
                TenantQuotas::default(),
                240,
                crash,
                3600,
            )
            .unwrap()
        };
        let (clean, _) = run(None);
        let (crashed, vc) = run(Some(SimTime::from_secs(60)));
        assert_eq!(vc.metrics().counter("head_crashes"), 1);
        assert_eq!(vc.metrics().counter("ha_takeovers"), 1);
        assert_eq!(
            crashed.arrivals_fingerprint, clean.arrivals_fingerprint,
            "the resumed arrival stream must be byte-identical to a crash-free run"
        );
        assert_eq!(crashed.jobs_submitted, clean.jobs_submitted);
        assert_eq!(
            crashed.jobs_completed + crashed.jobs_failed,
            crashed.jobs_submitted,
            "no submission may be lost across the failover"
        );
    }

    #[test]
    fn trace_driver_measures_overlap_and_serial_cap() {
        let trace = [(8u32, 10u64), (8, 10), (8, 10)];
        let (concurrent, _) = run_job_trace(spec(), &trace, usize::MAX, 24, 600).unwrap();
        assert_eq!(concurrent.peak_concurrency, 3);
        let (serial, _) = run_job_trace(spec(), &trace, 1, 24, 600).unwrap();
        assert_eq!(serial.peak_concurrency, 1);
        assert!(concurrent.makespan < serial.makespan);
        assert!(concurrent.mean_wait < serial.mean_wait);
    }
}
