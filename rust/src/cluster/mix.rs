//! Shared job-trace driver: submit a `(ranks, duration)` trace to a
//! fresh cluster and measure queue waits, overlap and makespan. Used
//! by the `vhpc mix` subcommand, `examples/job_mix.rs` and the
//! `ext_autoscale` bench so the three scenarios never drift apart.

use crate::cluster::head::{JobKind, JobState};
use crate::cluster::vcluster::VirtualCluster;
use crate::config::ClusterSpec;
use crate::sim::SimTime;
use anyhow::{anyhow, ensure, Result};

/// What a trace run measured.
#[derive(Debug, Clone)]
pub struct TraceOutcome {
    /// Mean submit-to-start wait across the trace, seconds.
    pub mean_wait: f64,
    /// Worst submit-to-start wait, seconds.
    pub max_wait: f64,
    /// Submit-burst to last-completion span, seconds.
    pub makespan: f64,
    /// Most jobs ever observed running at once.
    pub peak_concurrency: usize,
    /// Jobs that overtook a blocked head-of-queue job.
    pub backfill_starts: u64,
    /// Jobs requeued after losing a node (0 on a fault-free run; the
    /// chaos scenarios drive this through `faults::run_chaos_trace`).
    pub requeues: u64,
}

/// The 8-machine cluster the mix scenarios run on: 3 warm nodes, up to
/// 7 compute nodes, fast scaling intervals. Shared by the bench, the
/// example and the `vhpc mix` default so the scenarios stay comparable.
pub fn mix_spec(boot: SimTime) -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = 8;
    spec.machine_spec.boot_time = boot;
    spec.autoscale.min_nodes = 3;
    spec.autoscale.max_nodes = 7;
    spec.autoscale.interval = SimTime::from_secs(5);
    spec.autoscale.cooldown = SimTime::from_secs(10);
    spec.autoscale.idle_timeout = SimTime::from_secs(120);
    spec
}

/// The canonical bursty mix: `wide`-rank jobs bracket a stream of
/// narrow ones — the shape that serialized the seed's one-job head.
/// The 10-entry pattern repeats for `n_jobs` entries, so the bench, the
/// example and `vhpc mix` all measure the same workload shape.
pub fn bursty_trace(wide: u32, n_jobs: usize) -> Vec<(u32, u64)> {
    let pattern: [(u32, u64); 10] = [
        (wide, 60),
        (4, 30),
        (4, 30),
        (12, 45),
        (2, 20),
        (8, 40),
        (1, 15),
        (12, 45),
        (4, 25),
        (wide, 60),
    ];
    (0..n_jobs).map(|i| pattern[i % pattern.len()]).collect()
}

/// Drive `trace` (one `(ranks, duration_secs)` entry per job, all
/// submitted in one burst) through a fresh cluster built from `spec`.
/// `max_concurrent = 1` reproduces the seed's serial head. Waits for
/// `warmup_slots` advertised slots before submitting; errors if any
/// hostfile slot is ever double-booked or the trace has not drained
/// after `deadline_secs` of virtual time. Returns the outcome plus the
/// cluster for further inspection (metrics, completed records).
pub fn run_job_trace(
    spec: ClusterSpec,
    trace: &[(u32, u64)],
    max_concurrent: usize,
    warmup_slots: u32,
    deadline_secs: u64,
) -> Result<(TraceOutcome, VirtualCluster)> {
    let mut vc = VirtualCluster::new(spec)?;
    vc.state.head.max_concurrent = max_concurrent;
    vc.start();
    ensure!(
        vc.advance_until(SimTime::from_secs(600), |st| {
            st.head.slots_available() >= warmup_slots
        }),
        "cluster never advertised {warmup_slots} slots"
    );
    for (i, (ranks, secs)) in trace.iter().enumerate() {
        vc.submit(
            &format!("mix-{i}"),
            *ranks,
            JobKind::Synthetic { duration: SimTime::from_secs(*secs) },
        );
    }
    let t0 = vc.now();
    let deadline = t0 + SimTime::from_secs(deadline_secs);
    while vc.now() < deadline && vc.completed_jobs().len() < trace.len() {
        vc.advance(SimTime::from_secs(1));
        let overbooked = vc.state.head.overbooked_hosts();
        ensure!(overbooked.is_empty(), "double-booked hosts: {overbooked:?}");
    }
    // the scheduler records running-pool depth at every launch, where
    // the true peak is always attained — exact, unlike time sampling
    let peak = vc
        .metrics()
        .histogram("concurrent_jobs")
        .map(|h| h.max() as usize)
        .unwrap_or(0);
    ensure!(
        vc.completed_jobs().len() == trace.len(),
        "trace never drained: {}/{} jobs done after {deadline_secs}s",
        vc.completed_jobs().len(),
        trace.len()
    );
    let mut waits = Vec::with_capacity(trace.len());
    let mut last_finish = SimTime::ZERO;
    for rec in vc.completed_jobs() {
        match rec.state {
            JobState::Done { started, finished } => {
                waits.push(started.saturating_sub(rec.queued_at).as_secs_f64());
                last_finish = last_finish.max(finished);
            }
            ref other => return Err(anyhow!("job {} not done: {other:?}", rec.spec.name)),
        }
    }
    let outcome = TraceOutcome {
        peak_concurrency: peak,
        mean_wait: waits.iter().sum::<f64>() / waits.len().max(1) as f64,
        max_wait: waits.iter().cloned().fold(0.0, f64::max),
        makespan: last_finish.saturating_sub(t0).as_secs_f64(),
        backfill_starts: vc.metrics().counter("backfill_starts"),
        requeues: vc.metrics().counter("jobs_requeued"),
    };
    Ok((outcome, vc))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ClusterSpec {
        let mut spec = ClusterSpec::paper_testbed();
        spec.machine_spec.boot_time = SimTime::from_secs(5);
        spec
    }

    #[test]
    fn trace_driver_measures_overlap_and_serial_cap() {
        let trace = [(8u32, 10u64), (8, 10), (8, 10)];
        let (concurrent, _) = run_job_trace(spec(), &trace, usize::MAX, 24, 600).unwrap();
        assert_eq!(concurrent.peak_concurrency, 3);
        let (serial, _) = run_job_trace(spec(), &trace, 1, 24, 600).unwrap();
        assert_eq!(serial.peak_concurrency, 1);
        assert!(concurrent.makespan < serial.makespan);
        assert!(concurrent.mean_wait < serial.mean_wait);
    }
}
