//! The paper's system: a virtual HPC cluster with auto-scaling.
//!
//! [`vcluster::VirtualCluster`] composes every substrate — machines
//! (`hw`), the container engines (`dockyard`), the network (`vnet`),
//! service discovery (`consul`) and the MPI runtime (`mpi` + `runtime`) —
//! behind the workflow the paper describes: power up machines, deploy
//! containers from the Fig. 2 image, containers self-register, the head
//! node's consul-template keeps the hostfile fresh, jobs run via mpirun,
//! and the autoscaler grows/shrinks the node pool with demand.

pub mod autoscaler;
pub mod head;
pub mod metrics;
pub mod mix;
pub mod vcluster;

pub use autoscaler::{Autoscaler, Observation, ScaleAction};
pub use head::{Head, JobKind, JobRecord, JobSpec, JobState, StartedJob};
pub use metrics::{Histogram, Metrics};
pub use mix::{bursty_trace, mix_spec, run_job_trace, TraceOutcome};
pub use vcluster::{NodeState, VirtualCluster};
