//! The paper's system: a virtual HPC cluster with auto-scaling.
//!
//! [`vcluster::VirtualCluster`] composes every substrate — machines
//! (`hw`), the container engines (`dockyard`), the network (`vnet`),
//! service discovery (`consul`) and the MPI runtime (`mpi` + `runtime`) —
//! behind the workflow the paper describes: power up machines, deploy
//! containers from the Fig. 2 image, containers self-register, the head
//! node's consul-template keeps the hostfile fresh, jobs run via mpirun,
//! and the autoscaler grows/shrinks the node pool with demand.
//!
//! Scheduling is split into mechanism and policy: [`head::Head`] owns
//! the queue and per-job slot reservations (mechanism), while
//! [`policy::SchedulePolicy`] decides dispatch order — FIFO with
//! conservative backfill, EASY (reservation-based) backfill,
//! priorities with preemption, or per-tenant fair share
//! (`crate::tenancy`) — and whether reservations are carved
//! hostfile-order or packed rack-aware. [`autoscaler::Autoscaler`]
//! consumes a priority-weighted, tenant-share-capped demand signal,
//! and [`mix`] drives whole traces (fixed bursts or open-loop
//! multi-tenant arrival streams) through any policy for the benches
//! and the CLI.

pub mod autoscaler;
pub mod head;
pub mod metrics;
pub mod mix;
pub mod perf;
pub mod policy;
pub mod shard;
pub mod vcluster;

pub use autoscaler::{Autoscaler, Observation, ScaleAction};
pub use head::{Head, JobKind, JobRecord, JobSpec, JobState, StartedJob, SubmitOutcome};
pub use metrics::{jain_index, Histogram, Metrics, TenantBreakdown};
pub use mix::{
    bursty_trace, mix_spec, prioritized_trace, run_job_trace, run_policy_trace,
    run_tenant_trace, run_tenant_trace_ha, JobReq, TenantTraceOutcome, TraceOutcome,
};
pub use perf::{run_perf_trace, EngineBench, PerfOutcome, PhaseStats};
pub use policy::{PolicyKind, SchedulePolicy};
pub use shard::{
    run_sharded_chaos, run_sharded_mix, run_sharded_tenants, ComputeProfile, ShardMsg,
    ShardOutcome, ShardRunConfig,
};
pub use vcluster::{NodeState, VirtualCluster};

/// Canonical node name for machine index `idx` (machine 0 is the head,
/// so compute nodes start at `node02`). The zero-padding width is
/// derived from the cluster size, which keeps names in numeric order
/// under the lexicographic sorts the catalog and health registry use —
/// a fixed two-digit pad put `node100` before `node11` past 99 nodes.
pub fn node_name(machine_idx: usize, total_machines: u32) -> String {
    let width = total_machines.max(1).to_string().len().max(2);
    format!("node{:0w$}", machine_idx + 1, w = width)
}

#[cfg(test)]
mod tests {
    use super::node_name;

    #[test]
    fn node_names_keep_the_paper_shape_on_small_clusters() {
        assert_eq!(node_name(1, 3), "node02");
        assert_eq!(node_name(2, 3), "node03");
        assert_eq!(node_name(1, 99), "node02");
    }

    #[test]
    fn node_names_widen_past_99_nodes_and_sort_numerically() {
        assert_eq!(node_name(1, 150), "node002");
        assert_eq!(node_name(10, 150), "node011");
        assert_eq!(node_name(99, 150), "node100");
        let mut names: Vec<String> = (1..150).map(|i| node_name(i, 150)).collect();
        let sorted = {
            let mut s = names.clone();
            s.sort();
            s
        };
        names.sort_by_key(|n| n[4..].parse::<u32>().unwrap());
        assert_eq!(names, sorted, "lexicographic order must match numeric order");
    }
}
