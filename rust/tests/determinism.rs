//! Same-seed double-run determinism, end to end through every driver.
//!
//! These tests pin the property the `vhpc lint` rules exist to protect:
//! with hash-order iteration, wall-clock reads and ambient entropy kept
//! out of the tree, re-running any trace with the same seed must
//! produce a byte-identical [`Metrics::counters_snapshot`] fingerprint.
//! WAL replay, fault-plan replay and the sharded engine's partition
//! merge all assume exactly this — and the `sharded_*` tests push the
//! property one step further: the fingerprint must be invariant not
//! just across runs but across shard counts (1, 2 and 4).
//!
//! Pure control-plane (synthetic jobs only): runs under
//! `--no-default-features` in CI.

use std::collections::BTreeMap;
use vhpc::cluster::head::JobKind;
use vhpc::cluster::mix::{mix_spec, prioritized_trace, run_job_trace, run_tenant_trace};
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::obs::{FailAfterSink, MemSink, TraceSink};
use vhpc::cluster::perf::{perf_spec, run_perf_trace};
use vhpc::cluster::policy::SchedulePolicy;
use vhpc::cluster::{run_sharded_chaos, run_sharded_mix, run_sharded_tenants, ShardRunConfig};
use vhpc::config::ClusterSpec;
use vhpc::faults::{run_chaos_trace, FaultPlan};
use vhpc::ha::run_ha_trace;
use vhpc::sim::SimTime;
use vhpc::tenancy::arrivals::PopulationSpec;
use vhpc::tenancy::TenantQuotas;

type Fingerprint = BTreeMap<String, u64>;

fn fast_spec(machines: u32) -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = machines;
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec.autoscale.min_nodes = 2;
    spec.autoscale.max_nodes = machines - 1;
    spec.autoscale.interval = SimTime::from_secs(2);
    spec.autoscale.cooldown = SimTime::from_secs(4);
    spec.autoscale.idle_timeout = SimTime::from_secs(60);
    spec
}

fn assert_identical(a: &Fingerprint, b: &Fingerprint, what: &str) {
    // compare as rendered text so a mismatch prints the full diffable
    // fingerprints, not just the first unequal entry
    let render = |fp: &Fingerprint| {
        fp.iter().map(|(k, v)| format!("{k}={v}\n")).collect::<String>()
    };
    assert_eq!(render(a), render(b), "{what}: same-seed runs diverged");
}

/// The mixed workload driver: a bursty trace through the autoscaled
/// pool, twice, byte-identical counters.
#[test]
fn mix_trace_double_run_is_byte_identical() {
    let trace = [(8u32, 40u64), (16, 60), (4, 20), (12, 50), (8, 30)];
    let run = || {
        let (_, vc) = run_job_trace(fast_spec(4), &trace, usize::MAX, 24, 3600)
            .expect("mix trace must drain");
        vc.metrics().counters_snapshot()
    };
    assert_identical(&run(), &run(), "mix");
}

/// The multi-tenant driver: seeded arrivals under fair-share
/// scheduling, twice, byte-identical counters.
#[test]
fn tenant_trace_double_run_is_byte_identical() {
    let spec = || {
        let mut s = ClusterSpec::paper_testbed();
        s.machine_spec.boot_time = SimTime::from_secs(5);
        s
    };
    let mut pop = PopulationSpec::new(50, 31);
    pop.rate_per_sec = 0.05;
    let run = || {
        let (_, vc) = run_tenant_trace(
            spec(),
            pop,
            SchedulePolicy::fairshare(),
            TenantQuotas::default(),
            240,
            3600,
        )
        .expect("tenant trace must drain");
        vc.metrics().counters_snapshot()
    };
    assert_identical(&run(), &run(), "tenants");
}

/// The chaos driver: a seeded MTBF crash schedule against the recovery
/// pipeline, twice, byte-identical counters.
#[test]
fn chaos_trace_double_run_is_byte_identical() {
    let plan = FaultPlan::from_mtbf(7, 4, SimTime::from_secs(400), SimTime::from_secs(1200));
    assert!(!plan.is_empty(), "the schedule must contain at least one crash");
    let trace = [(8u32, 60u64), (12, 90), (8, 45), (16, 120)];
    let run = || {
        let (_, vc) = run_chaos_trace(fast_spec(4), &trace, &plan, 24, 5, 3600)
            .expect("chaos trace must drain");
        vc.metrics().counters_snapshot()
    };
    assert_identical(&run(), &run(), "chaos");
}

/// A shared config for the shard-invariance tests below: everything but
/// the shard count pinned, so the only variable across runs is how the
/// compute nodes are partitioned onto threads.
fn shard_cfg(shards: usize) -> ShardRunConfig {
    ShardRunConfig { shards, warmup_slots: 24, ..ShardRunConfig::default() }
}

/// The partitioned engine, mix workload: the same bursty trace at
/// shards 1, 2 and 4 must merge to byte-identical counters. This is
/// the acceptance property of the partition/comm subsystem — shard
/// count is an execution detail, never an observable.
#[test]
fn sharded_mix_is_shard_count_invariant() {
    let spec = || {
        let mut s = mix_spec(SimTime::from_secs(5));
        s.seed = 11;
        s
    };
    let jobs = prioritized_trace(24, 24);
    let base = run_sharded_mix(spec(), &jobs, SchedulePolicy::default(), &shard_cfg(1))
        .expect("1-shard mix must drain");
    assert_eq!(base.jobs_completed as usize, base.jobs_submitted);
    for shards in [2usize, 4] {
        let o = run_sharded_mix(spec(), &jobs, SchedulePolicy::default(), &shard_cfg(shards))
            .expect("sharded mix must drain");
        assert_eq!(o.shards, shards, "requested shard count must survive clamping");
        assert_eq!(o.windows, base.windows, "drain window drifted at {shards} shards");
        assert_identical(&o.fingerprint, &base.fingerprint, &format!("mix @ {shards} shards"));
    }
}

/// The partitioned engine, tenant workload: seeded open-loop arrivals
/// under fair share at shards 1, 2 and 4 — identical counters AND an
/// identical order-sensitive arrival-stream fingerprint (the conductor
/// owns the generator, so partitioning must not reorder submissions).
#[test]
fn sharded_tenants_is_shard_count_invariant() {
    let spec = || {
        let mut s = mix_spec(SimTime::from_secs(5));
        s.seed = 13;
        s
    };
    let mut pop = PopulationSpec::new(12, 31);
    pop.rate_per_sec = 0.08;
    let run = |shards| {
        run_sharded_tenants(
            spec(),
            pop,
            SchedulePolicy::fairshare(),
            TenantQuotas::default(),
            180,
            &shard_cfg(shards),
        )
        .expect("sharded tenant trace must drain")
    };
    let base = run(1);
    assert!(base.jobs_submitted > 0, "the arrival stream must produce work");
    assert_eq!(base.jobs_completed as usize, base.jobs_submitted);
    for shards in [2usize, 4] {
        let o = run(shards);
        assert_eq!(
            o.arrivals_fingerprint, base.arrivals_fingerprint,
            "arrival stream changed at {shards} shards"
        );
        assert_identical(&o.fingerprint, &base.fingerprint, &format!("tenants @ {shards} shards"));
    }
}

/// The partitioned engine, chaos workload: a seeded MTBF kill schedule
/// crossing shard boundaries at shards 1, 2 and 4 — kills land on the
/// window grid as boundary messages, so recovery and retries must merge
/// to byte-identical counters too. Seed 7 at this MTBF puts its first
/// kill ~98s in — inside the ~150s-minimum makespan of a 32-job trace —
/// and its second past 700s, so exactly one crash interrupts the run.
#[test]
fn sharded_chaos_is_shard_count_invariant() {
    let spec = || {
        let mut s = mix_spec(SimTime::from_secs(5));
        s.seed = 7;
        s
    };
    let jobs = prioritized_trace(16, 32);
    let run = |shards| {
        run_sharded_chaos(spec(), &jobs, SchedulePolicy::default(), 900.0, &shard_cfg(shards))
            .expect("sharded chaos trace must drain")
    };
    let base = run(1);
    assert!(
        base.fingerprint.get("machines_crashed").copied().unwrap_or(0) > 0,
        "the kill schedule must actually crash a machine"
    );
    for shards in [2usize, 4] {
        let o = run(shards);
        assert_identical(&o.fingerprint, &base.fingerprint, &format!("chaos @ {shards} shards"));
    }
}

/// The `vhpc perf` driver, scaled down: the throughput harness reads
/// wall clocks for its stats, but everything the simulation computes —
/// the arrival-stream fingerprint, the merged counter snapshot and its
/// digest — must double-run byte-identically on the calendar-queue
/// engine, and stay invariant across shard counts 1, 2 and 4. (The
/// harness also self-checks the engine microbench internally: the
/// calendar and reference-heap sides panic on a fired-count mismatch.)
#[test]
fn perf_driver_fingerprints_are_deterministic_and_shard_count_invariant() {
    let spec = || perf_spec(ClusterSpec::paper_testbed(), 6, 23);
    let run = |shards| {
        run_perf_trace(spec(), 150, 16, shards, 23, 240).expect("perf trace must drain")
    };
    let base = run(1);
    assert!(base.jobs_submitted > 0, "the scaled-down stream must produce work");
    assert!(base.jobs_completed > 0);
    let again = run(1);
    assert_eq!(
        base.arrivals_fingerprint, again.arrivals_fingerprint,
        "same-seed arrival streams diverged"
    );
    assert_identical(&base.counters, &again.counters, "perf double run");
    assert_eq!(base.counter_digest, again.counter_digest);
    for shards in [2usize, 4] {
        let o = run(shards);
        assert_eq!(
            o.arrivals_fingerprint, base.arrivals_fingerprint,
            "arrival stream changed at {shards} shards"
        );
        assert_identical(&o.counters, &base.counters, &format!("perf @ {shards} shards"));
    }
}

/// The sharded trace file is an observable, so it inherits the shard-
/// invariance contract: `--shards N --trace F` must write the same
/// *bytes* at shards 1, 2 and 4 (per-rank buffers merge in canonical
/// `(t_ns, kind, entity)` order at the barrier), and turning tracing on
/// must leave the counter fingerprint byte-identical to the untraced
/// run — on the sharded path, not just the single-process one.
#[test]
fn sharded_trace_files_are_byte_identical_across_shard_counts() {
    let spec = |trace: Option<String>| {
        let mut s = mix_spec(SimTime::from_secs(5));
        s.seed = 13;
        s.trace_path = trace;
        s
    };
    let mut pop = PopulationSpec::new(12, 31);
    pop.rate_per_sec = 0.08;
    let run = |shards, trace: Option<String>| {
        run_sharded_tenants(
            spec(trace),
            pop,
            SchedulePolicy::fairshare(),
            TenantQuotas::default(),
            180,
            &shard_cfg(shards),
        )
        .expect("sharded tenant trace must drain")
    };

    let untraced = run(1, None);
    assert_eq!((untraced.trace_events_written, untraced.trace_events_dropped), (0, 0));

    let path = |shards: usize| {
        std::env::temp_dir()
            .join(format!("vhpc_det_sharded_trace_{shards}shards.jsonl"))
            .to_string_lossy()
            .into_owned()
    };
    let base_path = path(1);
    let base = run(1, Some(base_path.clone()));
    let base_bytes = std::fs::read(&base_path).expect("1-shard trace file");
    assert!(base.trace_events_written > 0, "traced run wrote no events");
    assert_eq!(base.trace_events_dropped, 0);
    assert_eq!(
        base_bytes.iter().filter(|b| **b == b'\n').count() as u64,
        base.trace_events_written,
        "written count must match the file's line count"
    );
    assert_identical(&base.fingerprint, &untraced.fingerprint, "sharded traced vs untraced");

    for shards in [2usize, 4] {
        let p = path(shards);
        let o = run(shards, Some(p.clone()));
        let bytes = std::fs::read(&p).expect("sharded trace file");
        assert_identical(&o.fingerprint, &base.fingerprint, &format!("traced @ {shards} shards"));
        assert_eq!(o.trace_events_written, base.trace_events_written);
        assert!(
            bytes == base_bytes,
            "trace file diverged at {shards} shards ({} vs {} bytes)",
            bytes.len(),
            base_bytes.len()
        );
        let _ = std::fs::remove_file(&p);
    }
    let _ = std::fs::remove_file(&base_path);
}

/// Same property through the chaos driver: kills land on the window
/// grid as boundary messages, and the resulting NodeDown/Requeue event
/// flow must still serialize to the same bytes at any shard count.
#[test]
fn sharded_chaos_trace_files_are_byte_identical() {
    let spec = |trace: Option<String>| {
        let mut s = mix_spec(SimTime::from_secs(5));
        s.seed = 7;
        s.trace_path = trace;
        s
    };
    let jobs = prioritized_trace(16, 32);
    let path = |shards: usize| {
        std::env::temp_dir()
            .join(format!("vhpc_det_chaos_trace_{shards}shards.jsonl"))
            .to_string_lossy()
            .into_owned()
    };
    let run = |shards, trace: Option<String>| {
        run_sharded_chaos(spec(trace), &jobs, SchedulePolicy::default(), 900.0, &shard_cfg(shards))
            .expect("sharded chaos trace must drain")
    };
    let base_path = path(1);
    let base = run(1, Some(base_path.clone()));
    assert!(
        base.fingerprint.get("machines_crashed").copied().unwrap_or(0) > 0,
        "the kill schedule must actually crash a machine"
    );
    assert!(base.trace_events_written > 0);
    let base_bytes = std::fs::read(&base_path).expect("1-shard chaos trace file");
    for shards in [2usize, 4] {
        let p = path(shards);
        let o = run(shards, Some(p.clone()));
        let bytes = std::fs::read(&p).expect("sharded chaos trace file");
        assert_identical(&o.fingerprint, &base.fingerprint, &format!("chaos traced @ {shards} shards"));
        assert!(
            bytes == base_bytes,
            "chaos trace file diverged at {shards} shards ({} vs {} bytes)",
            bytes.len(),
            base_bytes.len()
        );
        let _ = std::fs::remove_file(&p);
    }
    let _ = std::fs::remove_file(&base_path);
}

/// Drive one fixed synthetic workload through a cluster with the given
/// trace sink (or none), returning the counter fingerprint plus the
/// bus's written/dropped tallies.
fn run_with_sink(sink: Option<Box<dyn TraceSink>>) -> (Fingerprint, u64, u64) {
    let mut vc = VirtualCluster::new(fast_spec(4)).expect("cluster");
    if let Some(s) = sink {
        vc.set_trace_sink(s);
    }
    vc.start();
    assert!(
        vc.advance_until(SimTime::from_secs(600), |st| st.head.slots_available() >= 24),
        "pool never warmed up"
    );
    for (i, (ranks, secs)) in [(8u32, 40u64), (16, 60), (4, 20), (12, 50)].iter().enumerate() {
        vc.submit(
            &format!("trace-job-{i}"),
            *ranks,
            JobKind::Synthetic { duration: SimTime::from_secs(*secs) },
        );
    }
    assert!(
        vc.advance_until(SimTime::from_secs(3600), |st| st.head.completed.len() >= 4),
        "jobs never drained"
    );
    vc.finish_trace();
    let written = vc.state.trace.events_written();
    let dropped = vc.state.trace.events_dropped();
    (vc.metrics().counters_snapshot(), written, dropped)
}

/// Observability must be a pure observer: the counter fingerprint of a
/// traced run — even one whose sink starts failing mid-run — is
/// byte-identical to the untraced run's. The drop counter lives on the
/// bus, outside [`Metrics`], and this is the test that keeps it there.
#[test]
fn traced_and_untraced_runs_fingerprint_byte_identical() {
    let (untraced, w0, d0) = run_with_sink(None);
    assert_eq!((w0, d0), (0, 0), "the disabled bus must write nothing");

    let sink = MemSink::new();
    let lines = sink.shared();
    let (traced, w1, d1) = run_with_sink(Some(Box::new(sink)));
    assert!(w1 > 0, "the healthy sink must have received events");
    assert_eq!(d1, 0, "the healthy sink must drop nothing");
    assert_eq!(
        lines.lock().unwrap().len() as u64,
        w1,
        "written count must match the sink's line count"
    );
    assert_identical(&untraced, &traced, "traced vs untraced");

    // the sink dies after 5 writes: the run must complete identically,
    // with the loss visible only in obs_events_dropped
    let (degraded, w2, d2) = run_with_sink(Some(Box::new(FailAfterSink::new(5))));
    assert_eq!(w2, 5, "the failing sink accepts exactly its budget");
    assert!(d2 > 0, "obs_events_dropped must count the lost events");
    assert_identical(&untraced, &degraded, "failing-sink vs untraced");
    for fp in [&traced, &degraded] {
        assert!(
            fp.keys().all(|k| !k.starts_with("obs_")),
            "obs drop/write tallies must never enter the Metrics fingerprint"
        );
    }
}

/// The HA driver: a head crash mid-trace, WAL replay, takeover — twice,
/// byte-identical counters (failover itself must replay exactly).
#[test]
fn ha_trace_double_run_is_byte_identical() {
    let spec = || {
        let mut s = ClusterSpec::paper_testbed();
        s.machines = 4;
        s.machine_spec.boot_time = SimTime::from_secs(5);
        s.autoscale.min_nodes = 3;
        s.autoscale.max_nodes = 3;
        s.autoscale.interval = SimTime::from_secs(2);
        s.autoscale.cooldown = SimTime::from_secs(4);
        s.autoscale.idle_timeout = SimTime::from_secs(600);
        s.ha.enabled = true;
        s
    };
    let trace = [(24u32, 90u64), (8, 30), (8, 40), (16, 50), (4, 20), (8, 60)];
    let run = || {
        let (_, vc) = run_ha_trace(spec(), &trace, Some(SimTime::from_secs(33)), 36, 2400)
            .expect("ha trace must drain");
        vc.metrics().counters_snapshot()
    };
    assert_identical(&run(), &run(), "ha");
}
