//! Head-node HA end-to-end: crash-consistent failover via the
//! replicated scheduler WAL.
//!
//! Pure control-plane (synthetic jobs only), so these run in the
//! `--no-default-features` CI configuration.

use std::collections::BTreeMap;
use vhpc::cluster::head::{Head, JobKind, JobState};
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::config::ClusterSpec;
use vhpc::faults::FaultPlan;
use vhpc::ha::failover::decode_wal_listing;
use vhpc::ha::run_ha_trace;
use vhpc::ha::wal::{replay, WAL_PREFIX};
use vhpc::sim::SimTime;
use vhpc::util::ids::MachineId;

/// 4 machines (3 compute, 36 slots), fixed pool (min == max) so the
/// determinism comparisons see zero autoscaler churn, HA on.
fn spec() -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = 4;
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec.autoscale.min_nodes = 3;
    spec.autoscale.max_nodes = 3;
    spec.autoscale.interval = SimTime::from_secs(2);
    spec.autoscale.cooldown = SimTime::from_secs(4);
    spec.autoscale.idle_timeout = SimTime::from_secs(600);
    spec.ha.enabled = true;
    spec
}

/// The canonical mixed trace: wide + narrow, long + short, so the
/// crash lands with jobs running, queued and already completed.
fn trace() -> Vec<(u32, u64)> {
    vec![(24, 90), (8, 30), (8, 40), (16, 50), (4, 20), (8, 60)]
}

/// Drop the counters a failover legitimately adds (HA bookkeeping, the
/// injected fault itself, and the takeover's extra hostfile render) —
/// everything else must match a crash-free run exactly.
fn scheduling_counters(fp: &BTreeMap<String, u64>) -> BTreeMap<String, u64> {
    fp.iter()
        .filter(|(k, _)| {
            !k.starts_with("ha_")
                && k.as_str() != "head_crashes"
                && k.as_str() != "faults_scheduled"
                && k.as_str() != "hostfile_renders"
        })
        .map(|(k, v)| (k.clone(), *v))
        .collect()
}

#[test]
fn failover_completes_every_job_without_charging_retry_budget() {
    let (o, vc) = run_ha_trace(spec(), &trace(), Some(SimTime::from_secs(33)), 36, 2400)
        .expect("ha trace must drain");
    assert_eq!(o.head_crashes, 1);
    assert_eq!(o.takeovers, 1, "exactly one standby promotion");
    assert_eq!(o.jobs_completed, o.jobs_submitted, "no submitted work may be lost");
    assert_eq!(
        o.requeues, 0,
        "the failover itself must not charge any job's retry budget"
    );
    assert!(
        o.failover_max > 0.0 && o.failover_max < 30.0,
        "failover MTTR should be lease-bounded, got {}",
        o.failover_max
    );
    assert!(o.wal_appends > 0, "the head must have journaled its mutations");
    for rec in vc.completed_jobs() {
        assert!(matches!(rec.state, JobState::Done { .. }), "{:?}", rec.state);
        assert_eq!(rec.attempt, 0, "no job may have been re-dispatched as a retry");
    }
    assert_eq!(vc.state.ha.epoch, 1);
    let leader = vc.state.consul.kv().get("vhpc/ha/leader").unwrap_or("");
    assert!(leader.starts_with("epoch 1 "), "leader record not updated: {leader}");
}

/// The chaos satellite: crash the head while a job is mid-flight
/// (dispatch logged, completion not) — the job is neither re-run nor
/// lost. Its completion event hits the dead head, is dropped by the
/// epoch fence, and the promoted standby's re-armed timer delivers it.
#[test]
fn head_crash_mid_dispatch_double_runs_nothing_and_loses_nothing() {
    let jobs = vec![(24u32, 22u64), (8, 60)];
    let (o, vc) = run_ha_trace(spec(), &jobs, Some(SimTime::from_secs(20)), 36, 1200)
        .expect("ha trace must drain");
    assert_eq!(o.jobs_completed, 2);
    assert_eq!(
        vc.metrics().counter("jobs_started"),
        2,
        "a job whose dispatch was logged must not be dispatched again"
    );
    assert_eq!(o.requeues, 0, "nothing requeues across a failover");
    assert!(
        vc.metrics().counter("ha_dropped_completions") >= 1,
        "the mid-outage completion must have been fenced at the dead head"
    );
    // the fenced completion was delivered by the new head instead:
    // every record is Done, none Failed
    for rec in vc.completed_jobs() {
        assert!(matches!(rec.state, JobState::Done { .. }), "{:?}", rec.state);
    }
}

/// Multiple standbys race the takeover through a compare-and-set on
/// the leadership record: exactly one wins the claim, the losers count
/// a loss and re-enter monitoring, and the promoted head finishes the
/// trace exactly like a lone standby would.
#[test]
fn multiple_standbys_race_and_exactly_one_wins() {
    let mut s = spec();
    s.ha.standbys = 3;
    let (o, vc) = run_ha_trace(s, &trace(), Some(SimTime::from_secs(33)), 36, 2400)
        .expect("ha trace must drain");
    assert_eq!(o.head_crashes, 1);
    assert_eq!(o.takeovers, 1, "exactly one standby may promote");
    assert_eq!(o.jobs_completed, o.jobs_submitted);
    assert_eq!(o.requeues, 0, "the failover still charges no retry budget");
    let m = vc.metrics();
    assert_eq!(m.counter("ha_claims_submitted"), 3, "every standby must claim");
    assert_eq!(m.counter("ha_takeover_won"), 1, "the CAS race has one winner");
    assert_eq!(
        m.counter("ha_takeover_lost"),
        2,
        "both losers must observe the foreign token and stand down"
    );
    // the winner's promotion published the bumped epoch over its claim
    let leader = vc.state.consul.kv().get("vhpc/ha/leader").unwrap_or("");
    assert!(leader.starts_with("epoch 1 "), "leader record not updated: {leader}");
}

/// The multi-standby race is deterministic: same seed, same winner,
/// same fingerprint.
#[test]
fn multi_standby_runs_are_deterministic() {
    let run = || {
        let mut s = spec();
        s.ha.standbys = 3;
        run_ha_trace(s, &trace(), Some(SimTime::from_secs(33)), 36, 2400).unwrap()
    };
    let (a, _) = run();
    let (b, _) = run();
    assert_eq!(a.fingerprint, b.fingerprint, "same-seed multi-standby runs diverged");
}

/// Same seed, head crash vs no crash: the scheduling outcome —
/// everything the metrics count except the failover's own bookkeeping
/// — must be byte-identical. This is the WAL-replay determinism
/// guarantee: the replayed head is the same head.
#[test]
fn crashed_run_matches_crash_free_run_modulo_failover_counters() {
    let (clean, _) =
        run_ha_trace(spec(), &trace(), None, 36, 2400).expect("clean run must drain");
    let (crashed, _) = run_ha_trace(spec(), &trace(), Some(SimTime::from_secs(33)), 36, 2400)
        .expect("crashed run must drain");
    assert_eq!(clean.takeovers, 0);
    assert_eq!(crashed.takeovers, 1);
    assert_eq!(
        scheduling_counters(&clean.fingerprint),
        scheduling_counters(&crashed.fingerprint),
        "a mid-trace head crash must not change the scheduling outcome"
    );
}

/// Two identical crashed runs replay byte-identically, WAL counters
/// included.
#[test]
fn crashed_runs_are_deterministic() {
    let (a, _) = run_ha_trace(spec(), &trace(), Some(SimTime::from_secs(25)), 36, 2400).unwrap();
    let (b, _) = run_ha_trace(spec(), &trace(), Some(SimTime::from_secs(25)), 36, 2400).unwrap();
    assert_eq!(a.fingerprint, b.fingerprint, "same-seed HA runs diverged");
    assert_eq!(a.replayed_events, b.replayed_events);
    assert_eq!(a.makespan, b.makespan);
}

/// Snapshots bound replay: with a short snapshot cadence the takeover
/// replays only the WAL tail, however long the run was.
#[test]
fn snapshotting_bounds_takeover_replay() {
    let mut s = spec();
    s.ha.snapshot_every = 8;
    let jobs: Vec<(u32, u64)> = (0..12u32)
        .map(|i| (4 + (i % 3) * 4, 20 + (i as u64 % 4) * 10))
        .collect();
    let (o, vc) =
        run_ha_trace(s, &jobs, Some(SimTime::from_secs(70)), 36, 2400).expect("must drain");
    assert_eq!(o.jobs_completed, o.jobs_submitted);
    assert_eq!(o.takeovers, 1);
    assert!(o.snapshots >= 1, "the short cadence must have snapshotted");
    assert!(
        vc.metrics().counter("ha_snapshot_restores") == 1,
        "the takeover must have restored from the snapshot"
    );
    assert!(
        o.replayed_events <= 8 + 16,
        "replay must be bounded by the snapshot cadence (plus one flush batch), got {}",
        o.replayed_events
    );
    assert!(
        o.wal_appends > o.replayed_events,
        "most of the log ({} appends) must have been truncated into snapshots, \
         yet {} events were replayed",
        o.wal_appends,
        o.replayed_events
    );
}

/// A submission that arrives while the head is down lands in the
/// replicated WAL and is scheduled by the promoted standby: no client
/// ever observes lost work.
#[test]
fn submissions_during_the_outage_are_replayed_by_the_standby() {
    let mut vc = VirtualCluster::new(spec()).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.slots_available() >= 36
    }));
    vc.submit("before", 16, JobKind::Synthetic { duration: SimTime::from_secs(120) });
    assert!(vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 1));
    vc.inject_faults(&FaultPlan::head_crash(SimTime::ZERO));
    vc.advance(SimTime::from_secs(2));
    assert!(vc.state.ha.head_down(), "the injected crash must take the head down");
    // the head is down: this submission can only exist in the WAL
    vc.submit("during", 8, JobKind::Synthetic { duration: SimTime::from_secs(30) });
    assert_eq!(vc.metrics().counter("jobs_submitted"), 2);
    let ok = vc.advance_until(SimTime::from_secs(600), |st| st.head.completed.len() == 2);
    assert!(ok, "both jobs must complete after the takeover");
    for rec in vc.completed_jobs() {
        assert!(matches!(rec.state, JobState::Done { .. }), "{:?}", rec.state);
    }
    assert_eq!(vc.metrics().counter("ha_takeovers"), 1);
}

/// A machine that dies while the head is down has no head to fail its
/// jobs; the takeover must validate every replayed reservation against
/// the live container map and fail those jobs over *before* re-arming
/// completions — otherwise a re-armed timer would complete the job on
/// dead slots (the phantom-completion bug the recovery pipeline fixed,
/// re-introduced for the outage window).
#[test]
fn machine_death_during_the_outage_is_not_a_phantom_completion() {
    let mut vc = VirtualCluster::new(spec()).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.slots_available() >= 36
    }));
    // 30 ranks spans all three compute nodes
    vc.submit("doomed", 30, JobKind::Synthetic { duration: SimTime::from_secs(120) });
    assert!(vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 1));
    vc.inject_faults(&FaultPlan::head_crash(SimTime::ZERO));
    vc.advance(SimTime::from_secs(1));
    assert!(vc.state.ha.head_down());
    // the machine dies under the job while no head is watching
    vc.kill_machine(MachineId::new(2));
    vc.advance(SimTime::from_secs(10));
    assert_eq!(vc.metrics().counter("ha_takeovers"), 1);
    assert!(
        vc.completed_jobs().is_empty(),
        "job completed on dead slots: {:?}",
        vc.completed_jobs()[0].state
    );
    assert_eq!(
        vc.metrics().counter("jobs_requeued"),
        1,
        "the takeover must fail the job over (machine death is a real fault)"
    );
    // the autoscaler replaces the dead machine and the rerun completes
    let ok = vc.advance_until(SimTime::from_secs(900), |st| !st.head.completed.is_empty());
    assert!(ok, "the failed-over job never completed after capacity returned");
    assert!(matches!(vc.completed_jobs()[0].state, JobState::Done { .. }));
    // the zombie attempt's original timer fired into the new epoch and
    // was fenced — never completing the rerun early
    assert!(vc.metrics().counter("ha_dropped_completions") >= 1);
}

/// A finished run's replicated WAL, as owned `(key, value)` pairs in
/// key (= sequence) order, plus the run's full decoded event list.
fn finished_wal() -> (Vec<(String, String)>, Vec<vhpc::ha::WalEvent>) {
    let (_o, vc) = run_ha_trace(spec(), &trace(), None, 36, 2400).expect("must drain");
    let listing: Vec<(String, String)> = vc
        .state
        .consul
        .kv()
        .list_prefix(WAL_PREFIX)
        .into_iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    let refs: Vec<(&str, &str)> = listing.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let (full, errs) = decode_wal_listing(&refs, 0);
    assert_eq!(errs, 0, "a healthy log must decode clean");
    assert!(!listing.is_empty());
    (listing, full)
}

fn lines_of(listing: &[(String, String)]) -> usize {
    listing.iter().map(|(_, v)| v.lines().count()).sum()
}

/// A crash that lands *between* flush batches loses whole engine
/// events only: the surviving log decodes byte-identically to a prefix
/// of the full run's event list, with zero decode errors.
#[test]
fn crash_between_wal_batches_replays_a_byte_identical_prefix() {
    let (listing, full) = finished_wal();
    assert!(
        listing.iter().any(|(_, v)| v.lines().count() >= 2),
        "the flush path must batch multiple mutations per engine event"
    );
    assert_eq!(lines_of(&listing), full.len(), "one event per line, all decoded");
    // chop off the last 1..=3 batches wholesale — each is everything a
    // single engine event journaled, so each cut is a valid crash point
    for cut in 1..=listing.len().min(3) {
        let survived = &listing[..listing.len() - cut];
        let refs: Vec<(&str, &str)> =
            survived.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
        let (events, errs) = decode_wal_listing(&refs, 0);
        assert_eq!(errs, 0, "batch boundaries are clean crash points");
        assert_eq!(events.len(), lines_of(survived));
        assert_eq!(
            events[..],
            full[..events.len()],
            "the surviving log is byte-identical to a prefix of the full log"
        );
    }
}

/// A write torn *mid-batch* must truncate replay at the hole: the
/// decoded log is the clean per-line prefix of the torn engine event,
/// and nothing from any later batch is spliced in behind the tear —
/// the half-flushed event's missing mutations can never be papered
/// over by subsequent entries.
#[test]
fn torn_mid_batch_wal_write_truncates_at_the_hole_and_splices_nothing() {
    let (listing, full) = finished_wal();
    // a multi-line batch with later batches behind it, so a splice —
    // were the reader willing to skip the hole — would have material
    let b = listing
        .iter()
        .enumerate()
        .position(|(i, (_, v))| v.lines().count() >= 2 && i + 1 < listing.len())
        .expect("need a multi-event batch that is not the final entry");
    let batch_lines: Vec<&str> = listing[b].1.lines().collect();
    let keep = batch_lines.len() / 2; // >= 1: the tear lands mid-batch
    let mut torn_value = batch_lines[..keep].join("\n");
    torn_value.push('\n');
    // the torn tail: the next line's first few bytes, as a partial
    // write would leave them — guaranteed undecodable (truncated tag)
    torn_value.push_str(&batch_lines[keep][..batch_lines[keep].len().min(3)]);
    let mut torn = listing.clone();
    torn[b].1 = torn_value;

    let refs: Vec<(&str, &str)> = torn.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let (events, errs) = decode_wal_listing(&refs, 0);
    assert_eq!(errs, 1, "exactly the torn line fails to decode");
    let expect = lines_of(&listing[..b]) + keep;
    assert_eq!(
        events.len(),
        expect,
        "replay is the full batches before the tear plus the torn batch's clean lines"
    );
    assert_eq!(
        events[..],
        full[..expect],
        "the truncated replay is a clean prefix — nothing reordered, nothing spliced"
    );
    // in particular: not a single event from the batches behind the
    // tear survived, even though they decode fine in isolation
    let behind: Vec<(&str, &str)> =
        listing[b + 1..].iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let (behind_events, behind_errs) = decode_wal_listing(&behind, 0);
    assert_eq!(behind_errs, 0);
    assert!(!behind_events.is_empty(), "there was real work behind the tear");
    // and the truncated log replays into a head without tripping any
    // invariant — the takeover path accepts a torn log as-is
    let mut head = Head::new();
    assert_eq!(replay(&mut head, &events), events.len());
}

/// The partial-partition satellite: an agent that can reach only a
/// minority (non-leader) consul server cannot commit TTL refreshes, so
/// its node flaps out of the hostfile; once the window closes the
/// existing anti-entropy path re-registers it. An agent whose subset
/// contains the leader never flaps.
#[test]
fn partial_partition_health_flap_resolves_via_anti_entropy() {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = 3;
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec.autoscale.min_nodes = 2;
    spec.autoscale.max_nodes = 2;
    spec.autoscale.interval = SimTime::from_secs(2);
    spec.autoscale.cooldown = SimTime::from_secs(4);
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
    }));
    let leader = vc.state.consul.leader_index().expect("quorum has a leader") as u32;
    let minority: Vec<u32> = (0..3u32).filter(|s| *s != leader).take(1).collect();
    vc.inject_faults(&FaultPlan::partial_partition(
        vec![2],
        minority,
        SimTime::ZERO,
        SimTime::from_secs(90),
    ));
    // writes can't commit without the leader: the TTL runs out and the
    // node drops from the hostfile
    let ok = vc.advance_until(SimTime::from_secs(150), |st| {
        st.head.hostfile().map(|h| h.hosts.len()) == Some(1)
    });
    assert!(ok, "partially partitioned node never flapped out: {}", vc.hostfile());
    assert_eq!(vc.metrics().counter("partial_partitions_injected"), 1);
    // the window closes: agent anti-entropy re-registers the reaped
    // service and the node returns
    let ok = vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
    });
    assert!(ok, "health flap never resolved after the heal: {}", vc.hostfile());
    assert!(
        vc.metrics().counter("agent_reregistrations") >= 1,
        "recovery must go through the existing anti-entropy path"
    );
    // control: a subset that contains the leader commits writes — no flap
    vc.inject_faults(&FaultPlan::partial_partition(
        vec![2],
        vec![leader],
        SimTime::ZERO,
        SimTime::from_secs(60),
    ));
    vc.advance(SimTime::from_secs(45));
    assert_eq!(
        vc.state.head.hostfile().map(|h| h.hosts.len()),
        Some(2),
        "a leader-reachable agent must keep its health check passing"
    );
}
