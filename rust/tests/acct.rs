//! End-to-end `vhpc acct` coverage: accounting derived from a chaos
//! run's replicated WAL must agree with the live cluster's own records
//! — attempt counts exactly, slot-seconds within decay tolerance — and
//! a truncated or corrupt log must degrade to a partial report, never
//! an error.
//!
//! Pure control-plane (synthetic jobs only): runs under
//! `--no-default-features` in CI.

use vhpc::cluster::head::JobKind;
use vhpc::cluster::mix::{mix_spec, prioritized_trace};
use vhpc::cluster::policy::SchedulePolicy;
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::cluster::{run_sharded_chaos, ShardRunConfig};
use vhpc::config::ClusterSpec;
use vhpc::ha::failover::decode_wal_listing;
use vhpc::ha::wal::WAL_PREFIX;
use vhpc::obs::acct::{from_trace_lines, from_wal, wal_to_trace, AcctFilter};
use vhpc::obs::MemSink;
use vhpc::sim::SimTime;
use vhpc::util::ids::MachineId;

/// Drive an HA-journaled cluster (full WAL retained: snapshots off)
/// with a live trace attached, through a mid-run machine kill, to
/// completion of every job. Returns the cluster plus the captured
/// trace lines.
fn chaos_run_with_wal() -> (VirtualCluster, Vec<String>) {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = 4;
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec.autoscale.min_nodes = 3;
    spec.autoscale.max_nodes = 3;
    spec.autoscale.interval = SimTime::from_secs(2);
    spec.autoscale.cooldown = SimTime::from_secs(4);
    spec.autoscale.idle_timeout = SimTime::from_secs(600);
    spec.ha.enabled = true;
    spec.ha.snapshot_every = 0; // keep the whole log: acct replays it

    let mut vc = VirtualCluster::new(spec).expect("cluster");
    // near-flat decay so the ledger comparison below is tight: over a
    // run of a few hundred virtual seconds the balance loses < 0.01%
    vc.state.head.ledger.half_life = SimTime::from_secs(10_000_000);
    let sink = MemSink::new();
    let lines = sink.shared();
    vc.set_trace_sink(Box::new(sink));

    vc.start();
    assert!(
        vc.advance_until(SimTime::from_secs(600), |st| st.head.slots_available() >= 24),
        "pool never warmed up"
    );
    let jobs: [(u32, u64, u64); 5] =
        [(8, 120, 1), (12, 90, 2), (4, 60, 1), (16, 150, 2), (8, 45, 1)];
    for (i, (ranks, secs, tenant)) in jobs.iter().enumerate() {
        vc.submit_job(
            &format!("acct-job-{i}"),
            *ranks,
            JobKind::Synthetic { duration: SimTime::from_secs(*secs) },
            0,
            *tenant,
        );
    }
    // let work start, then kill a compute machine: at least one running
    // job loses its reservation and requeues (budget is 3, so nothing
    // abandons — keeping the WAL-derived and live folds comparable)
    vc.advance(SimTime::from_secs(20));
    vc.kill_machine(MachineId::new(2));
    assert!(
        vc.advance_until(SimTime::from_secs(3600), |st| st.head.completed.len() >= 5),
        "jobs never drained"
    );
    vc.finish_trace();
    let captured = lines.lock().unwrap().clone();
    (vc, captured)
}

#[test]
fn wal_accounting_matches_live_trace_and_ledger() {
    let (vc, lines) = chaos_run_with_wal();
    let now = vc.now();

    let live = from_trace_lines(lines.iter().map(|s| s.as_str()));
    assert_eq!(live.skipped_lines, 0, "every emitted line must parse");
    assert_eq!(live.jobs.len(), 5);
    assert!(
        live.jobs.iter().any(|j| j.requeues > 0),
        "the machine kill must have requeued at least one job"
    );

    let kv = vc.state.consul.kv();
    let entries = kv.list_prefix(WAL_PREFIX);
    assert!(!entries.is_empty(), "the HA run must have journaled a WAL");
    let (events, decode_errors) = decode_wal_listing(&entries, 0);
    assert_eq!(decode_errors, 0, "the live WAL must decode cleanly");
    let replayed = from_wal(&events);

    // attempt counts exact; billing columns agree between the two
    // derivations (the WAL journals the same dispatch/loss boundaries
    // the live trace stamps)
    assert_eq!(replayed.jobs.len(), live.jobs.len());
    for (w, l) in replayed.jobs.iter().zip(live.jobs.iter()) {
        assert_eq!(w.job, l.job);
        assert_eq!(w.tenant, l.tenant);
        assert_eq!(w.attempts, l.attempts, "job {} attempts", w.job);
        assert_eq!(w.requeues, l.requeues, "job {} requeues", w.job);
        assert_eq!(w.state, l.state, "job {} state", w.job);
        assert!(
            (w.slot_seconds - l.slot_seconds).abs() < 1e-6,
            "job {}: wal {} vs live {} slot-seconds",
            w.job,
            w.slot_seconds,
            l.slot_seconds
        );
    }
    // completed records pin the attempt counts independently: the
    // record's attempt field is the 0-based final generation (bumped by
    // losses and preemptions but not by aborted launches, which
    // re-dispatch under the same generation — hence >=), and every
    // dispatch in the report is one initial start plus one per return
    // to the queue
    for rec in vc.state.head.completed.iter() {
        let j = replayed
            .jobs
            .iter()
            .find(|j| j.job == rec.spec.id.raw())
            .expect("every terminal record must appear in the report");
        assert!(j.attempts >= rec.attempt + 1, "job {}", rec.spec.id.raw());
        assert_eq!(
            j.attempts,
            1 + j.requeues + j.preemptions,
            "job {}",
            rec.spec.id.raw()
        );
    }
    // and the per-tenant rollup matches the head's own ledger within
    // the (near-flat) decay
    for t in &replayed.tenants {
        let ledger = vc.state.head.ledger.usage_at(t.tenant, now);
        let diff = (ledger - t.slot_seconds).abs();
        assert!(
            diff <= ledger.max(t.slot_seconds) * 0.01 + 1e-6,
            "tenant {}: ledger {ledger} vs acct {}",
            t.tenant,
            t.slot_seconds
        );
    }
}

/// WAL-vs-sharded-trace agreement through a mid-run chaos kill. The
/// sharded engine journals no WAL — its merged trace file IS the
/// durable accounting record — so the agreement is pinned from both
/// ends. (1) On the live HA fixture, which has both representations of
/// the same history, the WAL fold and the WAL *bridged into trace form*
/// and folded through `from_trace_lines` must produce field-identical
/// reports: the two derivations are the same accounting. (2) A sharded
/// chaos run's trace, folded through that same trace path, must then
/// agree exactly with the run's authoritative counter fingerprint —
/// the same counters the WAL-backed cluster journals — on completions,
/// requeues and preemptions, and satisfy the per-job attempt identity
/// the WAL fold pins.
#[test]
fn wal_and_sharded_trace_accounting_agree_through_a_chaos_kill() {
    // -- (1) same history, two representations, one report --
    let (vc, _) = chaos_run_with_wal();
    let kv = vc.state.consul.kv();
    let entries = kv.list_prefix(WAL_PREFIX);
    let (wal_events, errors) = decode_wal_listing(&entries, 0);
    assert_eq!(errors, 0, "the live WAL must decode cleanly");
    let direct = from_wal(&wal_events);
    let bridged_lines: Vec<String> =
        wal_to_trace(&wal_events).iter().map(|e| e.to_json_line()).collect();
    let bridged = from_trace_lines(bridged_lines.iter().map(|s| s.as_str()));
    assert_eq!(bridged.skipped_lines, 0, "bridged WAL lines must all parse");
    assert_eq!(bridged.jobs.len(), direct.jobs.len());
    for (b, d) in bridged.jobs.iter().zip(direct.jobs.iter()) {
        assert_eq!(b.job, d.job);
        assert_eq!(b.tenant, d.tenant);
        assert_eq!(b.attempts, d.attempts, "job {} attempts", b.job);
        assert_eq!(b.requeues, d.requeues, "job {} requeues", b.job);
        assert_eq!(b.preemptions, d.preemptions, "job {} preemptions", b.job);
        assert_eq!(b.state, d.state, "job {} state", b.job);
        assert!(
            (b.slot_seconds - d.slot_seconds).abs() < 1e-9,
            "job {}: bridged {} vs direct {} slot-seconds",
            b.job,
            b.slot_seconds,
            d.slot_seconds
        );
    }

    // -- (2) the sharded trace through the identical fold --
    let mut spec = mix_spec(SimTime::from_secs(5));
    spec.seed = 7; // first kill ~98s in: mid-run, inside the makespan
    let trace_path = std::env::temp_dir()
        .join("vhpc_acct_sharded_chaos_trace.jsonl")
        .to_string_lossy()
        .into_owned();
    spec.trace_path = Some(trace_path.clone());
    let jobs = prioritized_trace(16, 32);
    let cfg = ShardRunConfig { shards: 4, warmup_slots: 24, ..ShardRunConfig::default() };
    let o = run_sharded_chaos(spec, &jobs, SchedulePolicy::default(), 900.0, &cfg)
        .expect("sharded chaos trace must drain");
    assert!(
        o.fingerprint.get("machines_crashed").copied().unwrap_or(0) > 0,
        "the kill schedule must actually crash a machine"
    );
    let text = std::fs::read_to_string(&trace_path).expect("sharded trace file");
    let _ = std::fs::remove_file(&trace_path);
    let report = from_trace_lines(text.lines());
    assert_eq!(report.skipped_lines, 0, "every merged line must parse");
    assert_eq!(report.jobs.len(), o.jobs_submitted, "every submission must appear");

    let counter = |k: &str| o.fingerprint.get(k).copied().unwrap_or(0);
    let completed = report.jobs.iter().filter(|j| j.state == "completed").count() as u64;
    let requeues: u64 = report.jobs.iter().map(|j| j.requeues as u64).sum();
    let preemptions: u64 = report.jobs.iter().map(|j| j.preemptions as u64).sum();
    assert_eq!(completed, counter("jobs_completed"), "completions: trace fold vs counters");
    assert_eq!(requeues, counter("jobs_requeued"), "requeues: trace fold vs counters");
    assert_eq!(preemptions, counter("jobs_preempted"), "preemptions: trace fold vs counters");
    assert!(requeues > 0, "the mid-run kill must have requeued at least one job");
    for j in &report.jobs {
        assert_eq!(
            j.attempts,
            1 + j.requeues + j.preemptions,
            "job {}: the WAL fold's attempt identity must hold on the sharded trace",
            j.job
        );
    }
    // the per-tenant rollup is exactly the per-job sums, as it is for
    // the WAL fold
    for t in &report.tenants {
        let sum: f64 = report
            .jobs
            .iter()
            .filter(|j| j.tenant == t.tenant)
            .map(|j| j.slot_seconds)
            .sum();
        assert!(
            (t.slot_seconds - sum).abs() < 1e-6,
            "tenant {}: rollup {} vs job sum {sum}",
            t.tenant,
            t.slot_seconds
        );
    }
}

#[test]
fn truncated_or_corrupt_wal_degrades_to_partial_report() {
    let (vc, _) = chaos_run_with_wal();
    let kv = vc.state.consul.kv();
    let entries = kv.list_prefix(WAL_PREFIX);
    let (full_events, _) = decode_wal_listing(&entries, 0);
    let full = from_wal(&full_events);
    assert_eq!(full.jobs.len(), 5);

    // corrupt a mid-log batch: decode truncates at the tear and the
    // fold reports whatever the clean prefix supports — no panic, no Err
    let mut owned: Vec<(String, String)> =
        entries.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    let mid = owned.len() / 2;
    owned[mid].1 = "not a wal record".to_string();
    let refs: Vec<(&str, &str)> =
        owned.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let (prefix_events, errors) = decode_wal_listing(&refs, 0);
    assert_eq!(errors, 1, "the tear must be counted");
    assert!(prefix_events.len() < full_events.len(), "replay must truncate at the tear");
    let partial = from_wal(&prefix_events);
    assert!(partial.events < full.events);
    assert!(partial.jobs.len() <= full.jobs.len());
    // the partial report is a prefix view, not a reshuffle: every job
    // it knows exists in the full report under the same tenant
    for p in &partial.jobs {
        let f = full.jobs.iter().find(|f| f.job == p.job).expect("prefix job");
        assert_eq!(p.tenant, f.tenant);
    }
}

#[test]
fn corrupt_trace_lines_are_counted_and_skipped() {
    let (_, mut lines) = chaos_run_with_wal();
    let n = lines.len();
    lines.insert(n / 2, "{\"ev\":\"garbage".to_string());
    lines.push("not json at all".to_string());
    let report = from_trace_lines(lines.iter().map(|s| s.as_str()));
    assert_eq!(report.skipped_lines, 2, "bad lines are counted, not fatal");
    assert_eq!(report.jobs.len(), 5, "good lines still fold");

    // filters compose on the degraded report too
    let t1 = report.filtered(&AcctFilter {
        tenant: Some(1),
        state: None,
        since: None,
    });
    assert!(t1.jobs.iter().all(|j| j.tenant == 1));
    assert_eq!(t1.jobs.len(), 3);
}
