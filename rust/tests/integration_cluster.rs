//! Integration tests: the full system composed through the public API.

use vhpc::cluster::head::{JobKind, JobState};
use vhpc::cluster::vcluster::{NodeState, VirtualCluster};
use vhpc::config::ClusterSpec;
use vhpc::runtime::Runtime;
use vhpc::sim::SimTime;
use vhpc::util::ids::MachineId;

fn fast_spec() -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec
}

fn have_artifacts() -> bool {
    Runtime::default_dir().join("manifest.txt").exists()
}

/// The paper's full workflow with REAL compute: cluster up, hostfile via
/// consul-template, 16-rank Jacobi through the head node's scheduler.
#[test]
fn end_to_end_jacobi_job_via_head_node() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut vc = VirtualCluster::new(fast_spec()).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| st.head.slots_available() >= 16));
    vc.submit("it-jacobi", 16, JobKind::Jacobi { px: 4, py: 4, tile: 64, steps: 40 });
    assert!(vc.advance_until(SimTime::from_secs(3600), |st| !st.head.completed.is_empty()));
    let rec = &vc.completed_jobs()[0];
    assert!(matches!(rec.state, JobState::Done { .. }), "{:?}", rec.state);
    let (steps, residual) = rec.result.expect("jacobi result");
    assert_eq!(steps, 40);
    assert!(residual.is_finite() && residual > 0.0);
    assert!(vc.metrics().counter("jobs_completed") == 1);
    assert!(vc.metrics().histogram("job_comm_seconds").is_some());
}

/// Config-file-driven cluster: text config -> running cluster.
#[test]
fn cluster_from_config_text() {
    let spec = ClusterSpec::from_text(
        "[cluster]\nname = \"cfg-test\"\nmachines = 4\nbridge = \"bridge0\"\nslots_per_node = 4\n\
         [machine]\nboot_secs = 3\n\
         [autoscale]\nmin_nodes = 3\nmax_nodes = 3\n",
    )
    .unwrap();
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.hostfile().map(|h| h.hosts.len()) == Some(3)
    }));
    assert_eq!(vc.state.head.slots_available(), 12);
}

/// Two jobs that fit together (16 + 8 <= 24 slots) overlap under the
/// slot-aware scheduler: the shorter one finishes first, and queue
/// latency is recorded for both.
#[test]
fn job_queue_overlaps_when_slots_allow() {
    let mut vc = VirtualCluster::new(fast_spec()).unwrap();
    vc.start();
    let a = vc.submit("a", 16, JobKind::Synthetic { duration: SimTime::from_secs(20) });
    let b = vc.submit("b", 8, JobKind::Synthetic { duration: SimTime::from_secs(10) });
    assert!(vc.advance_until(SimTime::from_secs(3600), |st| st.head.completed.len() == 2));
    let done = vc.completed_jobs();
    assert_eq!(done[0].spec.id, b, "shorter overlapping job completes first");
    assert_eq!(done[1].spec.id, a);
    if let (JobState::Done { started: sb, .. }, JobState::Done { finished: fa, .. }) =
        (&done[0].state, &done[1].state)
    {
        assert!(sb < fa, "job b must start before a finishes (overlap)");
    } else {
        panic!("jobs not done");
    }
    assert_eq!(
        vc.metrics().histogram("job_queue_seconds").map(|h| h.count()),
        Some(2)
    );
}

/// With the head capped at one job (the seed's serial scheduler), FIFO
/// order is preserved: b only starts after a finishes.
#[test]
fn serial_cap_preserves_fifo_order() {
    let mut vc = VirtualCluster::new(fast_spec()).unwrap();
    vc.state.head.max_concurrent = 1;
    vc.start();
    let a = vc.submit("a", 16, JobKind::Synthetic { duration: SimTime::from_secs(20) });
    let b = vc.submit("b", 8, JobKind::Synthetic { duration: SimTime::from_secs(10) });
    assert!(vc.advance_until(SimTime::from_secs(3600), |st| st.head.completed.len() == 2));
    let done = vc.completed_jobs();
    assert_eq!(done[0].spec.id, a);
    assert_eq!(done[1].spec.id, b);
    if let (JobState::Done { finished: fa, .. }, JobState::Done { started: sb, .. }) =
        (&done[0].state, &done[1].state)
    {
        assert!(sb >= fa, "job b started before a finished");
    } else {
        panic!("jobs not done");
    }
}

/// Kill a machine mid-cluster with autoscaling disabled: the hostfile
/// shrinks; jobs needing more slots than remain queue forever until we
/// re-provision manually.
#[test]
fn failure_and_manual_recovery() {
    let mut spec = fast_spec();
    spec.autoscale.enabled = false;
    spec.autoscale.min_nodes = 2;
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
    }));
    vc.kill_machine(MachineId::new(2));
    assert!(vc.advance_until(SimTime::from_secs(120), |st| {
        st.head.hostfile().map(|h| h.hosts.len()) == Some(1)
    }));
    // 16-rank job can't run on 12 slots
    vc.submit("stuck", 16, JobKind::Synthetic { duration: SimTime::from_secs(5) });
    vc.advance(SimTime::from_secs(60));
    assert!(vc.completed_jobs().is_empty());
    // manual recovery
    vc.power_on(MachineId::new(2));
    assert!(vc.advance_until(SimTime::from_secs(300), |st| !st.head.completed.is_empty()));
}

/// The Fig. 4 shape: every container IP in the hostfile is leased from
/// the bridge subnet and routes to a distinct machine.
#[test]
fn hostfile_ips_match_bridge_leases() {
    let mut vc = VirtualCluster::new(fast_spec()).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
    }));
    let hf = vc.state.head.hostfile().unwrap();
    let subnet = vhpc::vnet::Cidr::parse("10.10.0.0/16").unwrap();
    let mut machines = std::collections::HashSet::new();
    for host in &hf.hosts {
        assert!(subnet.contains(host.addr), "{} outside {}", host.addr, subnet);
        let cid = vc.state.ip_to_container[&host.addr];
        let m = vc.state.fabric.lock().unwrap().machine_of(cid).unwrap();
        assert!(machines.insert(m), "two hostfile entries on one machine");
    }
}

/// Provisioning metrics are recorded and plausible.
#[test]
fn provisioning_metrics_recorded() {
    let mut vc = VirtualCluster::new(fast_spec()).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.node_states.iter().filter(|s| **s == NodeState::Ready).count() == 3
    }));
    let m = vc.metrics();
    assert_eq!(m.counter("machines_powered_on"), 3);
    assert_eq!(m.counter("nodes_ready"), 3);
    assert!(m.counter("bytes_pulled") > 3 * 20_000_000);
    let prov = m.histogram("provision_seconds").unwrap();
    assert_eq!(prov.count(), 3);
    assert!(prov.mean() > 5.0); // at least the boot time
}
