//! Differential suite pinning the calendar-queue [`Engine`] to the
//! boxed-closure [`ClosureHeapEngine`] it replaced.
//!
//! Both engines promise the same contract: events fire in `(time,
//! insertion seq)` order, `schedule_after(0)` lands on the current tick
//! behind everything already queued there, and past-dated events clamp
//! to `now`. The reference heap implements that contract with
//! `BinaryHeap<Reverse<(SimTime, u64)>>` — small enough to be obviously
//! correct — so here we drive both through the same seeded random
//! schedules (same-tick ties, zero-delay self-reschedules, far-future
//! delays that land in the calendar's overflow map) and demand the pop
//! orders match event for event.
//!
//! On divergence the failure printout carries the seed, the first
//! divergent index, and a window of ops around it — enough to replay
//! and shrink by hand without a property-testing framework.

use vhpc::sim::{CalendarQueue, ClosureHeapEngine, Engine, SimEvent, SimTime};
use vhpc::util::Rng;

/// One fired event, as both engines must observe it.
type Fired = (u64, u32, u32); // (now_ns, op id, hop index)

/// A differential program: op `i` first fires at `starts[i]` and then
/// self-reschedules once per entry of `hops[i]` (a 0 entry is a
/// zero-delay reschedule: same tick, new seq).
struct Program {
    seed: u64,
    starts: Vec<u64>,
    hops: Vec<Vec<u64>>,
}

/// Delay classes that exercise every scheduling path: exact ties and
/// zero delays, sub-bucket nanoseconds, multi-bucket seconds, and
/// far-future draws past the default calendar ring (~275s horizon).
fn draw_delay(rng: &mut Rng) -> u64 {
    match rng.gen_range(10) {
        0 | 1 => 0,                                      // zero-delay reschedule
        2 | 3 | 4 => rng.gen_range(1_000_000),           // intra-bucket (<1ms)
        5 | 6 | 7 => rng.gen_range(20_000_000_000),      // ring range (<20s)
        8 => 1_000_000_000 * (200 + rng.gen_range(400)), // 200..600s: wraps / overflow
        _ => 1_000_000_000_000 + rng.gen_range(1_000_000_000_000), // deep overflow
    }
}

fn gen_program(seed: u64, ops: usize) -> Program {
    let mut rng = Rng::new(seed);
    let mut starts = Vec::with_capacity(ops);
    let mut hops = Vec::with_capacity(ops);
    for i in 0..ops {
        // cluster start times onto a coarse grid so unrelated ops
        // collide on the same tick and the seq tiebreak does real work
        let at = rng.gen_range(50) * 1_000_000;
        // every 7th op starts at an already-used instant verbatim
        let at = if i % 7 == 3 && !starts.is_empty() {
            starts[i / 2]
        } else {
            at
        };
        starts.push(at);
        let n = rng.gen_range(4) as usize;
        hops.push((0..n).map(|_| draw_delay(&mut rng)).collect());
    }
    Program { seed, starts, hops }
}

struct DiffState {
    log: Vec<Fired>,
    hops: Vec<Vec<u64>>,
}

struct Op {
    id: u32,
    hop: u32,
}

impl SimEvent<DiffState> for Op {
    fn fire(self, st: &mut DiffState, eng: &mut Engine<DiffState, Op>) {
        st.log.push((eng.now().as_nanos(), self.id, self.hop));
        if let Some(&delay) = st.hops[self.id as usize].get(self.hop as usize) {
            eng.schedule_after(
                SimTime::from_nanos(delay),
                Op { id: self.id, hop: self.hop + 1 },
            );
        }
    }
}

fn run_calendar(p: &Program) -> (Vec<Fired>, u64) {
    let mut st = DiffState { log: Vec::new(), hops: p.hops.clone() };
    let mut eng: Engine<DiffState, Op> = Engine::new();
    for (i, &at) in p.starts.iter().enumerate() {
        eng.schedule_at(SimTime::from_nanos(at), Op { id: i as u32, hop: 0 });
    }
    eng.run_to_completion(&mut st);
    (st.log, eng.fired())
}

fn heap_fire(st: &mut DiffState, eng: &mut ClosureHeapEngine<DiffState>, id: u32, hop: u32) {
    st.log.push((eng.now().as_nanos(), id, hop));
    if let Some(&delay) = st.hops[id as usize].get(hop as usize) {
        eng.schedule_after(SimTime::from_nanos(delay), move |s, e| {
            heap_fire(s, e, id, hop + 1)
        });
    }
}

fn run_heap(p: &Program) -> (Vec<Fired>, u64) {
    let mut st = DiffState { log: Vec::new(), hops: p.hops.clone() };
    let mut eng: ClosureHeapEngine<DiffState> = ClosureHeapEngine::new();
    for (i, &at) in p.starts.iter().enumerate() {
        let id = i as u32;
        eng.schedule_at(SimTime::from_nanos(at), move |s, e| heap_fire(s, e, id, 0));
    }
    eng.run_to_completion(&mut st);
    (st.log, eng.fired())
}

/// Assert identical pop order, with a shrink-friendly printout on the
/// first divergence.
fn assert_same_order(p: &Program, cal: &[Fired], heap: &[Fired]) {
    if cal == heap {
        return;
    }
    let i = cal
        .iter()
        .zip(heap.iter())
        .position(|(a, b)| a != b)
        .unwrap_or_else(|| cal.len().min(heap.len()));
    let lo = i.saturating_sub(3);
    let hi = (i + 4).min(cal.len().max(heap.len()));
    let mut ctx = String::new();
    for j in lo..hi {
        ctx.push_str(&format!(
            "  [{j}] calendar {:?}  heap {:?}{}\n",
            cal.get(j),
            heap.get(j),
            if j == i { "   <-- first divergence" } else { "" }
        ));
    }
    panic!(
        "engines diverged (seed {}, {} ops): calendar fired {}, heap fired {}, \
         first divergence at event {i}\n{ctx}\
         replay: gen_program({}, {})",
        p.seed,
        p.starts.len(),
        cal.len(),
        heap.len(),
        p.seed,
        p.starts.len(),
    );
}

fn check_seed(seed: u64, ops: usize) {
    let p = gen_program(seed, ops);
    let (cal, cal_fired) = run_calendar(&p);
    let (heap, heap_fired) = run_heap(&p);
    assert_same_order(&p, &cal, &heap);
    assert_eq!(cal_fired, heap_fired, "fired counters diverged (seed {seed})");
    assert_eq!(cal.len() as u64, cal_fired, "log length is the fired count");
    // times must be monotone — both engines, same contract
    for w in cal.windows(2) {
        assert!(w[0].0 <= w[1].0, "time went backwards: {w:?} (seed {seed})");
    }
}

#[test]
fn differential_random_schedules() {
    for seed in 0..24u64 {
        check_seed(seed * 7919 + 1, 60);
    }
}

#[test]
fn differential_tie_heavy_schedules() {
    // a tiny time grid forces nearly everything onto shared ticks, so
    // ordering is carried almost entirely by the insertion seq
    for seed in [3u64, 17, 404, 9001] {
        let mut p = gen_program(seed, 80);
        for at in p.starts.iter_mut() {
            *at %= 3_000_000; // 3 grid points at the 1ms cluster step
        }
        for hops in p.hops.iter_mut() {
            for d in hops.iter_mut() {
                *d %= 2_000_000; // reschedules collide too
            }
        }
        let (cal, _) = run_calendar(&p);
        let (heap, _) = run_heap(&p);
        assert_same_order(&p, &cal, &heap);
    }
}

#[test]
fn differential_overflow_heavy_schedules() {
    // bias everything far past the calendar ring so the overflow map
    // and its drain-back path carry the whole schedule
    for seed in [5u64, 88, 123456] {
        let mut p = gen_program(seed, 40);
        for (i, at) in p.starts.iter_mut().enumerate() {
            *at += (i as u64 % 5) * 400_000_000_000; // 0..1600s spread
        }
        let (cal, _) = run_calendar(&p);
        let (heap, _) = run_heap(&p);
        assert_same_order(&p, &cal, &heap);
    }
}

#[test]
fn zero_delay_chains_fire_in_seq_order_on_one_tick() {
    // two ops at the same instant, each rescheduling itself twice with
    // zero delay: the contract interleaves them by seq, never batches
    let p = Program {
        seed: 0,
        starts: vec![1_000, 1_000],
        hops: vec![vec![0, 0], vec![0, 0]],
    };
    let (cal, _) = run_calendar(&p);
    let (heap, _) = run_heap(&p);
    assert_same_order(&p, &cal, &heap);
    // op 0 was inserted first: hop 0 of each op in id order, then the
    // zero-delay hops in the order they were (re)scheduled
    assert_eq!(
        cal,
        vec![
            (1_000, 0, 0),
            (1_000, 1, 0),
            (1_000, 0, 1),
            (1_000, 1, 1),
            (1_000, 0, 2),
            (1_000, 1, 2),
        ]
    );
}

// ---------------------------------------------------------------------
// CalendarQueue direct tests at tiny geometry, where wrap-around and
// overflow drain are hit constantly instead of at the 275s horizon
// ---------------------------------------------------------------------

#[test]
fn tiny_geometry_pops_sorted_with_seq_ties() {
    // 8 buckets x 16ns: a 128ns ring horizon
    let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(4, 3);
    let mut rng = Rng::new(42);
    let mut expect: Vec<(u64, u64, u32)> = Vec::new();
    for seq in 0..200u64 {
        let t = rng.gen_range(1_000); // ~8x the ring horizon: heavy overflow
        q.push(t, seq, seq as u32);
        expect.push((t, seq, seq as u32));
    }
    expect.sort();
    let mut got = Vec::new();
    while let Some(e) = q.pop() {
        got.push(e);
    }
    assert_eq!(got, expect, "tiny-geometry pop order is (key, seq) sorted");
}

#[test]
fn tiny_geometry_interleaves_pushes_with_pops() {
    let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(4, 3);
    let mut rng = Rng::new(7);
    let mut reference: Vec<(u64, u64)> = Vec::new();
    let mut now = 0u64;
    let mut seq = 0u64;
    let mut popped = Vec::new();
    let mut expected = Vec::new();
    for _ in 0..500 {
        if rng.gen_range(3) > 0 || reference.is_empty() {
            // push at or after the cursor, sometimes exactly at it
            let t = now + rng.gen_range(300);
            q.push(t, seq, seq as u32);
            reference.push((t, seq));
            seq += 1;
        } else {
            reference.sort();
            let (t, s) = reference.remove(0);
            expected.push((t, s));
            let got = q.pop().expect("queue and reference agree on length");
            popped.push((got.0, got.1));
            now = t.max(now);
        }
    }
    assert_eq!(popped, expected, "interleaved pops follow the sorted reference");
}

#[test]
fn peek_matches_pop_across_bucket_advances() {
    let mut q: CalendarQueue<u8> = CalendarQueue::with_geometry(4, 2);
    for (seq, t) in [500u64, 3, 3, 64, 17, 1000, 64].into_iter().enumerate() {
        q.push(t, seq as u64, 0);
    }
    while !q.is_empty() {
        let peeked = q.peek_key().expect("non-empty");
        let (t, s, _) = q.pop().expect("non-empty");
        assert_eq!(peeked, (t, s), "peek_key must preview exactly the next pop");
    }
    assert_eq!(q.pop(), None);
    assert_eq!(q.peek_key(), None);
}
