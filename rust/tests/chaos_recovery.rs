//! Integration tests for the faults subsystem: failure detection, job
//! requeue and capacity replacement, end to end through the public API.

use vhpc::cluster::head::{JobKind, JobState};
use vhpc::cluster::vcluster::{NodeState, VirtualCluster};
use vhpc::config::ClusterSpec;
use vhpc::faults::{run_chaos_trace, FaultEvent, FaultKind, FaultPlan};
use vhpc::sim::SimTime;
use vhpc::util::ids::MachineId;

fn fast_spec(machines: u32) -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = machines;
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec.autoscale.min_nodes = 2;
    spec.autoscale.max_nodes = machines - 1;
    spec.autoscale.interval = SimTime::from_secs(2);
    spec.autoscale.cooldown = SimTime::from_secs(4);
    spec.autoscale.idle_timeout = SimTime::from_secs(60);
    spec
}

/// The headline scenario: a machine dies mid-job. The hostfile shrinks,
/// the victim job is requeued with progress credit, the autoscaler
/// boots a replacement, and the job reruns to completion.
#[test]
fn killed_machine_requeues_job_and_boots_replacement() {
    let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.slots_available() >= 24
    }));
    let id = vc.submit("victim", 16, JobKind::Synthetic { duration: SimTime::from_secs(120) });
    assert!(vc.advance_until(SimTime::from_secs(60), |st| st.head.running.len() == 1));
    let powered_before = vc.metrics().counter("machines_powered_on");

    vc.kill_machine(MachineId::new(2));
    // immediate detection: the job fails out of the running pool
    assert!(vc.state.head.running.is_empty(), "job kept running on a dead node");
    assert_eq!(vc.metrics().counter("jobs_requeued"), 1);

    // the hostfile shrinks once the dead node's TTL expires (or sooner,
    // via the launch-time quarantine)
    assert!(
        vc.advance_until(SimTime::from_secs(120), |st| {
            st.head.hostfile().map(|h| h.hosts.len()) == Some(1)
        }),
        "dead node never left the hostfile: {}",
        vc.hostfile()
    );

    // the autoscaler boots a replacement and the job reruns to completion
    assert!(
        vc.advance_until(SimTime::from_secs(600), |st| !st.head.completed.is_empty()),
        "victim job never completed after the crash"
    );
    let rec = &vc.completed_jobs()[0];
    assert_eq!(rec.spec.id, id);
    assert!(matches!(rec.state, JobState::Done { .. }), "{:?}", rec.state);
    assert!(
        vc.metrics().counter("machines_powered_on") > powered_before,
        "no replacement machine was powered on"
    );
    assert_eq!(
        vc.metrics().histogram("job_mttr_seconds").map(|h| h.count()),
        Some(1),
        "MTTR must be recorded for the recovered job"
    );
}

/// A hang is not a crash: the machine stays alive, its heartbeats stop.
/// The node must drop out of the hostfile (TTL) and — when the agent
/// recovers — re-register and rejoin without being re-provisioned.
#[test]
fn hung_node_drops_out_and_rejoins_via_anti_entropy() {
    let mut spec = fast_spec(3);
    spec.autoscale.enabled = false;
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(300), |st| {
        st.head.slots_available() >= 24
    }));
    let plan = FaultPlan::scripted(vec![FaultEvent {
        at: SimTime::from_secs(1),
        kind: FaultKind::Hang { machine: 2, duration: SimTime::from_secs(90) },
    }]);
    vc.inject_faults(&plan);
    assert!(
        vc.advance_until(SimTime::from_secs(150), |st| {
            st.head.hostfile().map(|h| h.hosts.len()) == Some(1)
        }),
        "hung node never left the hostfile"
    );
    // still powered and Ready — nothing crashed
    assert_eq!(vc.node_state(MachineId::new(2)), NodeState::Ready);
    assert!(
        vc.advance_until(SimTime::from_secs(300), |st| {
            st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
        }),
        "hung node never rejoined after recovering"
    );
    assert!(vc.metrics().counter("agent_reregistrations") >= 1);
    assert_eq!(vc.metrics().counter("machines_powered_on"), 3, "no reboot for a hang");
}

/// Correlated rack-level failure: a `rack_outage` plan kills every
/// machine on one rack in the same tick. All affected jobs requeue,
/// and the autoscaler replaces the rack's worth of capacity.
#[test]
fn rack_outage_requeues_jobs_and_replaces_the_racks_capacity() {
    // 7 machines over 3 racks: rack0 = {head, m1, m2}, rack1 = {m3, m4,
    // m5}, rack2 = {m6}. Rack 1 is all compute — the outage target.
    let mut spec = fast_spec(7);
    spec.racks = 3;
    spec.autoscale.min_nodes = 6;
    spec.autoscale.max_nodes = 6;
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.start();
    assert!(
        vc.advance_until(SimTime::from_secs(600), |st| {
            st.head.slots_available() >= 72
        }),
        "all six compute nodes must come up"
    );
    // a full-width job holds slots on every node, rack 1 included
    vc.submit("spans-racks", 72, JobKind::Synthetic { duration: SimTime::from_secs(100) });
    assert!(vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 1));
    let powered_before = vc.metrics().counter("machines_powered_on");

    vc.inject_faults(&FaultPlan::rack_outage(1, SimTime::from_secs(1)));
    assert!(
        vc.advance_until(SimTime::from_secs(30), |st| st.head.running.is_empty()),
        "the spanning job must fail out of the running pool"
    );
    assert_eq!(vc.metrics().counter("rack_outages_injected"), 1);
    assert_eq!(
        vc.metrics().counter("machines_killed"),
        3,
        "the whole rack must die in the same tick"
    );
    assert_eq!(
        vc.metrics().counter("jobs_requeued"),
        1,
        "every affected job must requeue (once — later kills are no-ops on it)"
    );
    assert!(
        vc.state.head.reserved_addrs().is_empty(),
        "the dead rack's reservations must be released"
    );

    // the autoscaler boots replacements until the rack's capacity is
    // back, and the requeued job reruns to completion
    assert!(
        vc.advance_until(SimTime::from_secs(900), |st| {
            st.head.slots_available() >= 72
        }),
        "capacity never recovered after the rack outage"
    );
    assert!(
        vc.metrics().counter("machines_powered_on") >= powered_before + 3,
        "three replacement machines must boot"
    );
    assert!(
        vc.advance_until(SimTime::from_secs(900), |st| !st.head.completed.is_empty()),
        "the requeued job never completed"
    );
    assert!(matches!(
        vc.completed_jobs()[0].state,
        JobState::Done { .. }
    ));
}

/// Same seed, same chaos: two runs of one seeded crash schedule must
/// produce identical counter fingerprints and account for every job.
#[test]
fn same_seed_chaos_is_deterministic() {
    let spec = || fast_spec(4);
    let plan = FaultPlan::from_mtbf(7, 4, SimTime::from_secs(400), SimTime::from_secs(1200));
    assert!(!plan.is_empty(), "the schedule must contain at least one crash");
    let trace = [(8u32, 60u64), (12, 90), (8, 45), (16, 120)];
    let run = || run_chaos_trace(spec(), &trace, &plan, 24, 5, 3600).unwrap().0;
    let a = run();
    let b = run();
    assert_eq!(a.fingerprint, b.fingerprint, "same seed must replay identically");
    assert_eq!(a.jobs_completed, b.jobs_completed);
    assert_eq!(a.jobs_completed + a.jobs_abandoned, trace.len());
    assert!(a.mttr_max.is_finite());
}

/// The full menagerie — crashes, hangs, flaps, deploy failures and a
/// partition — against the recovery pipeline: every job is eventually
/// accounted for and the run stays deterministic.
#[test]
fn mixed_chaos_accounts_for_every_job() {
    let mut spec = fast_spec(5);
    spec.autoscale.max_nodes = 4;
    let plan = FaultPlan::chaos_mix(11, 5, 6, SimTime::from_secs(600));
    let trace = [(8u32, 40u64), (4, 30), (12, 60), (8, 45), (4, 30), (16, 60)];
    let (o, _vc) = run_chaos_trace(spec, &trace, &plan, 24, 5, 3600).unwrap();
    assert_eq!(o.jobs_completed + o.jobs_abandoned, trace.len());
    assert!(o.jobs_completed >= 1, "chaos must not wipe out every job");
    assert!(o.mttr_max.is_finite());
    assert!(o.goodput >= 0.0);
}
