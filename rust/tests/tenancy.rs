//! Integration tests for the tenancy layer: arrival-stream determinism,
//! ledger decay, quota enforcement, and the fairshare-vs-priority
//! ordering contract — end to end through the public API.

use vhpc::cluster::head::{Head, JobKind, JobSpec, SubmitOutcome};
use vhpc::cluster::mix::run_tenant_trace;
use vhpc::cluster::policy::SchedulePolicy;
use vhpc::config::ClusterSpec;
use vhpc::sim::SimTime;
use vhpc::tenancy::arrivals::{stream_fingerprint, tenant_counts, ArrivalGen, PopulationSpec};
use vhpc::tenancy::{QuotaAction, TenantQuotas, UsageLedger};
use vhpc::util::ids::JobId;

fn job(id: u32, ranks: u32, secs: u64, priority: i32, tenant: u64) -> JobSpec {
    JobSpec {
        id: JobId::new(id),
        name: format!("job{id}"),
        ranks,
        kind: JobKind::Synthetic { duration: SimTime::from_secs(secs) },
        priority,
        tenant,
    }
}

/// Same seed, same stream — the counter fingerprint discipline the
/// faults subsystem established (`ext_faults`), applied to arrivals.
#[test]
fn arrival_generator_is_deterministic_in_the_seed() {
    let spec = PopulationSpec::new(1_000, 99);
    let a = ArrivalGen::new(spec).take(600);
    let b = ArrivalGen::new(spec).take(600);
    assert_eq!(a, b, "same-seed streams must be byte-identical");
    assert_eq!(stream_fingerprint(&a), stream_fingerprint(&b));
    assert_eq!(tenant_counts(&a), tenant_counts(&b));
    let c = ArrivalGen::new(PopulationSpec::new(1_000, 100)).take(600);
    assert_ne!(
        stream_fingerprint(&a),
        stream_fingerprint(&c),
        "different seeds must produce different streams"
    );
}

/// One half-life halves the balance; two quarter it.
#[test]
fn ledger_decay_halves_after_one_half_life() {
    let mut ledger = UsageLedger::new(SimTime::from_secs(900));
    ledger.charge(7, 64.0, SimTime::ZERO);
    let at_half = ledger.usage_at(7, SimTime::from_secs(900));
    assert!((at_half - 32.0).abs() < 1e-9, "expected 32, got {at_half}");
    let at_two = ledger.usage_at(7, SimTime::from_secs(1800));
    assert!((at_two - 16.0).abs() < 1e-9, "expected 16, got {at_two}");
}

/// Over-quota submissions are rejected deterministically, and the
/// rejection never bleeds onto other tenants.
#[test]
fn queued_job_quota_rejects_over_quota_submissions() {
    let mut head = Head::new();
    head.quotas = TenantQuotas {
        max_queued_jobs: 2,
        over_quota: QuotaAction::Reject,
        ..Default::default()
    };
    assert!(matches!(head.submit(job(0, 4, 10, 0, 1), SimTime::ZERO), SubmitOutcome::Queued));
    assert!(matches!(head.submit(job(1, 4, 10, 0, 1), SimTime::ZERO), SubmitOutcome::Queued));
    match head.submit(job(2, 4, 10, 0, 1), SimTime::ZERO) {
        SubmitOutcome::Rejected { spec, reason } => {
            assert_eq!(spec.id, JobId::new(2));
            assert_eq!(spec.tenant, 1, "the rejected spec keeps its tenant");
            assert!(reason.contains("quota"), "{reason}");
        }
        other => panic!("third submission must be rejected, got {other:?}"),
    }
    // a different tenant still queues freely
    assert!(matches!(head.submit(job(3, 4, 10, 0, 2), SimTime::ZERO), SubmitOutcome::Queued));
    assert_eq!(head.tenant_queued_jobs(1), 2);
    assert_eq!(head.tenant_queued_jobs(2), 1);
}

/// The ordering regression the fairshare policy exists for: a tenant
/// with heavy decayed usage loses the head of the queue to a fresh
/// tenant — even when the heavy tenant's job was submitted earlier AND
/// carries a higher priority. The priority policy, given the exact
/// same queue, picks the other way.
#[test]
fn fairshare_orders_against_usage_where_priority_orders_against_it() {
    let build = |policy: SchedulePolicy| {
        let mut head = Head::new();
        head.policy = policy;
        head.hostfile_text = "10.10.0.2 slots=12\n".into();
        // tenant 1 burned 5000 slot-seconds recently; tenant 2 is fresh
        head.ledger.charge(1, 5000.0, SimTime::ZERO);
        head.submit(job(0, 12, 30, 5, 1), SimTime::ZERO); // hog, urgent, first
        head.submit(job(1, 12, 30, 0, 2), SimTime::ZERO); // fresh, batch, second
        head
    };
    let mut fair = build(SchedulePolicy::fairshare());
    let first = fair.start_next(SimTime::from_secs(1)).unwrap();
    assert_eq!(
        first.spec.id,
        JobId::new(1),
        "fairshare must seat the fresh tenant first"
    );
    let mut pri = build(SchedulePolicy::priority());
    let first = pri.start_next(SimTime::from_secs(1)).unwrap();
    assert_eq!(
        first.spec.id,
        JobId::new(0),
        "priority ignores the ledger and seats the urgent hog"
    );
}

/// Fault requeues preserve tenant attribution end to end: the rerun's
/// spec carries the same tenant, and the lost attempt's slot-seconds
/// were charged to that tenant.
#[test]
fn requeue_preserves_tenant_attribution_and_charges_the_ledger() {
    let mut head = Head::new();
    head.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
    head.submit(job(0, 16, 120, 0, 9), SimTime::ZERO);
    head.start_next(SimTime::ZERO).unwrap();
    let out = head.handle_lost_job(JobId::new(0), SimTime::from_secs(30), "node died");
    assert!(
        matches!(out, vhpc::cluster::head::LossOutcome::Requeued { .. }),
        "{out:?}"
    );
    let (requeued, _) = head.queue.front().unwrap();
    assert_eq!(requeued.tenant, 9, "the rerun must charge the same tenant");
    let usage = head.ledger.usage_at(9, SimTime::from_secs(30));
    assert!(
        (usage - 16.0 * 30.0).abs() < 1e-6,
        "16 slots x 30s must land on tenant 9's ledger: {usage}"
    );
}

/// End to end through the cluster: a small open-loop run drains, stays
/// deterministic, and the fairshare run is byte-identical across two
/// same-seed executions.
#[test]
fn tenant_trace_end_to_end_is_deterministic() {
    let spec = || {
        let mut s = ClusterSpec::paper_testbed();
        s.machine_spec.boot_time = SimTime::from_secs(5);
        s
    };
    let mut pop = PopulationSpec::new(50, 31);
    pop.rate_per_sec = 0.05;
    let run = || {
        run_tenant_trace(
            spec(),
            pop,
            SchedulePolicy::fairshare(),
            TenantQuotas::default(),
            240,
            3600,
        )
        .expect("small tenant trace must drain")
        .0
    };
    let a = run();
    let b = run();
    assert!(a.jobs_submitted > 0);
    assert_eq!(a.jobs_completed + a.jobs_failed, a.jobs_submitted);
    assert_eq!(a.arrivals_fingerprint, b.arrivals_fingerprint);
    assert_eq!(a.fingerprint, b.fingerprint, "metric counters must replay");
    assert_eq!(a.fairness_slowdown.to_bits(), b.fairness_slowdown.to_bits());
}

/// Deferral under sustained pressure: with a queued-job quota of 1 and
/// Defer, a burst from one tenant is admitted one job at a time and
/// still fully completes.
#[test]
fn deferred_burst_drains_one_admission_at_a_time() {
    let mut head = Head::new();
    head.quotas = TenantQuotas {
        max_queued_jobs: 1,
        over_quota: QuotaAction::Defer,
        ..Default::default()
    };
    head.hostfile_text = "10.10.0.2 slots=12\n".into();
    for i in 0..4u32 {
        head.submit(job(i, 4, 10, 0, 1), SimTime::ZERO);
    }
    assert_eq!(head.queue.len(), 1);
    assert_eq!(head.deferred_jobs(), 3);
    let mut started = Vec::new();
    for tick in 0..8u64 {
        while let Some(s) = head.start_next(SimTime::from_secs(tick)) {
            started.push(s.spec.id);
        }
        // complete everything running so quota slots free up
        let ids: Vec<JobId> = head.running.keys().copied().collect();
        for id in ids {
            head.finish(id);
        }
    }
    assert_eq!(
        started,
        vec![JobId::new(0), JobId::new(1), JobId::new(2), JobId::new(3)],
        "deferred jobs must admit FIFO within the tenant"
    );
    assert_eq!(head.deferred_jobs(), 0);
}
