//! Integration tests for the policy-driven scheduler: EASY backfill
//! under faults (reservations must be recomputed when a crash removes
//! a running job's predicted finish), and preemption edge cases
//! (attempt-guarded completion, fault-retry budget isolation).

use vhpc::cluster::head::{JobKind, JobState};
use vhpc::cluster::policy::SchedulePolicy;
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::config::ClusterSpec;
use vhpc::faults::{FaultEvent, FaultKind, FaultPlan};
use vhpc::sim::SimTime;
use vhpc::util::ids::MachineId;

fn fast_spec(machines: u32) -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = machines;
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec.autoscale.min_nodes = machines - 1;
    spec.autoscale.max_nodes = machines - 1;
    spec.autoscale.interval = SimTime::from_secs(2);
    spec.autoscale.cooldown = SimTime::from_secs(4);
    spec.autoscale.idle_timeout = SimTime::from_secs(600);
    spec
}

fn done_count(vc: &VirtualCluster) -> usize {
    vc.completed_jobs()
        .iter()
        .filter(|r| matches!(r.state, JobState::Done { .. }))
        .count()
}

/// Satellite regression: an EASY reservation is derived from a running
/// job's predicted finish; when a fault kills that job the prediction
/// is gone and the reservation must be recomputed from live state —
/// otherwise backfill keeps starving the blocked head job. The policy
/// recomputes per dispatch attempt, so the whole trace must drain even
/// when the anchor job crashes mid-run.
#[test]
fn easy_reservation_recomputed_after_crash_plan() {
    let mut spec = fast_spec(4); // 3 compute nodes, 36 slots
    spec.autoscale.min_nodes = 3;
    spec.autoscale.max_nodes = 3;
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.state.head.policy = SchedulePolicy::easy();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(600), |st| {
        st.head.slots_available() >= 36
    }));
    // the long job anchors the head job's EASY reservation
    vc.submit("long", 12, JobKind::Synthetic { duration: SimTime::from_secs(200) });
    // full-width head job, blocked until the cluster drains
    vc.submit("wide", 36, JobKind::Synthetic { duration: SimTime::from_secs(30) });
    // short jobs EASY happily backfills ahead of the wide job
    for i in 0..4 {
        vc.submit(
            &format!("short-{i}"),
            8,
            JobKind::Synthetic { duration: SimTime::from_secs(15) },
        );
    }
    assert!(
        vc.advance_until(SimTime::from_secs(60), |st| st.head.running.len() >= 2),
        "long job + a backfilled short must be running"
    );
    // kill the machine hosting the long job's slots (the first compute
    // node carries the 12-rank reservation): its predicted finish —
    // the reservation anchor — dies with it
    let plan = FaultPlan::scripted(vec![FaultEvent {
        at: SimTime::from_secs(5),
        kind: FaultKind::Crash { machine: 1 },
    }]);
    vc.inject_faults(&plan);
    // everything must still drain: the requeued long job, the wide
    // head job and every short — no stale reservation wedges the head
    assert!(
        vc.advance_until(SimTime::from_secs(1200), |st| st.head.completed.len() == 6),
        "trace wedged after the crash: {} done, {} running, {} queued",
        vc.completed_jobs().len(),
        vc.state.head.running.len(),
        vc.state.head.queue.len()
    );
    assert_eq!(done_count(&vc), 6, "every job must complete (retry budget absorbs the crash)");
    assert!(vc.metrics().counter("jobs_requeued") >= 1, "the long job must have requeued");
    assert!(vc.metrics().counter("backfill_starts") >= 1, "EASY must have backfilled");
}

/// A high-priority arrival checkpoints-and-requeues running batch work
/// when the free pool cannot seat it.
#[test]
fn high_priority_job_preempts_running_batch_work() {
    let mut vc = VirtualCluster::new(fast_spec(3)).unwrap(); // 24 slots
    vc.state.head.policy = SchedulePolicy::priority();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(600), |st| {
        st.head.slots_available() >= 24
    }));
    vc.submit("batch", 24, JobKind::Synthetic { duration: SimTime::from_secs(300) });
    assert!(vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 1));
    vc.submit_with_priority(
        "urgent",
        24,
        JobKind::Synthetic { duration: SimTime::from_secs(30) },
        5,
    );
    // the urgent job must be running within a couple of scheduler ticks
    assert!(
        vc.advance_until(SimTime::from_secs(10), |st| {
            st.head.running.values().any(|r| r.spec.name == "urgent")
        }),
        "urgent job never started"
    );
    assert_eq!(vc.metrics().counter("jobs_preempted"), 1);
    assert_eq!(
        vc.metrics().counter("jobs_requeued"),
        0,
        "preemption must not be recorded as a fault requeue"
    );
    // both jobs complete: urgent immediately, batch with credit after
    assert!(vc.advance_until(SimTime::from_secs(900), |st| st.head.completed.len() == 2));
    assert_eq!(done_count(&vc), 2);
}

/// Satellite edge case: preempting a job mid-run keeps attempt-guarded
/// completion correct — the completion event scheduled for the
/// preempted attempt must not complete the requeued job early.
#[test]
fn preemption_mid_run_keeps_attempt_guarded_completion_correct() {
    let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
    vc.state.head.policy = SchedulePolicy::priority();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(600), |st| {
        st.head.slots_available() >= 24
    }));
    vc.submit("batch", 24, JobKind::Synthetic { duration: SimTime::from_secs(100) });
    assert!(vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 1));
    // let the batch job run ~40s, then preempt it with a 30s urgent job
    vc.advance(SimTime::from_secs(40));
    let preempt_at = vc.now();
    vc.submit_with_priority(
        "urgent",
        24,
        JobKind::Synthetic { duration: SimTime::from_secs(30) },
        5,
    );
    assert!(vc.advance_until(SimTime::from_secs(60), |st| {
        st.head.completed.iter().any(|r| r.spec.name == "urgent")
    }));
    // past the batch job's ORIGINAL completion time: the stale timer
    // from the preempted attempt must not mark it done (it restarted
    // with ~60s remaining after the urgent job's 30s)
    let past_stale_timer = preempt_at + SimTime::from_secs(65);
    vc.advance(past_stale_timer.saturating_sub(vc.now()));
    let batch_done = vc
        .completed_jobs()
        .iter()
        .any(|r| r.spec.name == "batch" && matches!(r.state, JobState::Done { .. }));
    assert!(
        !batch_done,
        "stale completion event from the preempted attempt fired: {:?}",
        vc.completed_jobs()
    );
    // with its remaining duration served, it completes for real
    assert!(vc.advance_until(SimTime::from_secs(300), |st| st.head.completed.len() == 2));
    let batch = vc
        .completed_jobs()
        .iter()
        .find(|r| r.spec.name == "batch")
        .expect("batch record");
    let JobState::Done { started, finished } = batch.state else {
        panic!("batch not done: {:?}", batch.state);
    };
    // the rerun owes only the uncredited remainder (~60s), and it must
    // have finished after the original 100s timer expired
    let rerun = finished.saturating_sub(started).as_secs_f64();
    assert!(
        (50.0..80.0).contains(&rerun),
        "rerun must serve ~60s remaining, served {rerun:.0}s"
    );
    assert_eq!(vc.metrics().counter("jobs_preempted"), 1);
}

/// Satellite edge case: a preempted job's requeue must not charge the
/// fault retry budget — after a preemption, a genuine node loss still
/// has the full budget available.
#[test]
fn preempted_jobs_retry_does_not_charge_fault_budget() {
    let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
    vc.state.head.policy = SchedulePolicy::priority();
    vc.state.head.max_retries = 1; // exactly one fault loss allowed
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(600), |st| {
        st.head.slots_available() >= 24
    }));
    vc.submit("batch", 24, JobKind::Synthetic { duration: SimTime::from_secs(120) });
    assert!(vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 1));
    // preemption one: would exhaust a budget of 1 if it charged it
    vc.submit_with_priority(
        "urgent",
        24,
        JobKind::Synthetic { duration: SimTime::from_secs(20) },
        5,
    );
    assert!(vc.advance_until(SimTime::from_secs(60), |st| {
        st.head.completed.iter().any(|r| r.spec.name == "urgent")
    }));
    assert_eq!(vc.metrics().counter("jobs_preempted"), 1);
    // wait until the batch job is running again, then kill one of its
    // machines: this genuine loss charges the budget (1 of 1) and the
    // job must still be requeued, not abandoned
    assert!(vc.advance_until(SimTime::from_secs(60), |st| {
        st.head.running.values().any(|r| r.spec.name == "batch")
    }));
    vc.kill_machine(MachineId::new(2));
    assert_eq!(vc.metrics().counter("jobs_requeued"), 1, "fault loss must requeue");
    assert_eq!(
        vc.metrics().counter("jobs_lost"),
        0,
        "budget of 1 must survive the earlier preemption"
    );
    // the autoscaler reboots the dead machine and the job completes
    assert!(
        vc.advance_until(SimTime::from_secs(1200), |st| st.head.completed.len() == 2),
        "batch job never recovered: {:?}",
        vc.completed_jobs()
    );
    assert_eq!(done_count(&vc), 2);
}

/// Topology-aware placement packs jobs into single racks end to end
/// (rack map populated by provisioning, spread reported in metrics).
#[test]
fn topo_aware_cluster_reports_rack_spread_of_one() {
    let mut spec = fast_spec(7); // 6 compute nodes
    spec.racks = 3; // racks of 2-3 machines
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.state.head.policy = SchedulePolicy::fifo().with_topo_aware(true);
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(600), |st| {
        st.head.slots_available() >= 72
    }));
    // two 24-rank jobs fit a rack's node pair each; the 12-rank job
    // fits a single node — every reservation can stay inside one rack
    for (i, ranks) in [24u32, 24, 12].iter().enumerate() {
        vc.submit(
            &format!("packed-{i}"),
            *ranks,
            JobKind::Synthetic { duration: SimTime::from_secs(20) },
        );
    }
    assert!(vc.advance_until(SimTime::from_secs(120), |st| st.head.completed.len() == 3));
    let spread = vc
        .metrics()
        .histogram("job_rack_spread")
        .expect("rack spread must be recorded");
    assert_eq!(spread.count(), 3);
    assert_eq!(spread.max(), 1.0, "every 24-rank job must pack into one rack");
    assert!(vc.state.head.overbooked_hosts().is_empty());
}
