//! Fig. 5 — the consul-template service-discovery scheme.
//!
//! Measures what the scheme buys: the time from "container becomes
//! ready" to "hostfile updated on the head node", as the cluster grows —
//! versus the manual baseline the paper describes (§III-C: retrieve each
//! container's floating IP by hand and rebuild the hostfile), modeled at
//! 30 s of admin work per node.
//!
//! Expected shape: consul time is flat-ish (gossip + template poll),
//! manual is linear in N.

use vhpc::bench::{banner, print_table};
use vhpc::cluster::vcluster::{NodeState, VirtualCluster};
use vhpc::config::ClusterSpec;
use vhpc::sim::SimTime;
use vhpc::util::ids::MachineId;

/// Bring up a cluster of `n` compute nodes; return per-node delay from
/// node-Ready to the hostfile including it.
fn measure(n: u32) -> (Vec<f64>, f64) {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = n + 1;
    spec.machine_spec.boot_time = SimTime::from_secs(60);
    spec.autoscale.min_nodes = n;
    spec.autoscale.max_nodes = n;
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.start();

    let mut ready_at: Vec<Option<SimTime>> = vec![None; n as usize + 1];
    let mut in_hostfile_at: Vec<Option<SimTime>> = vec![None; n as usize + 1];
    let deadline = SimTime::from_secs(1200);
    while vc.now() < deadline {
        vc.advance(SimTime::from_millis(10));
        for i in 1..=n {
            let idx = i as usize;
            if ready_at[idx].is_none()
                && vc.node_state(MachineId::new(i)) == NodeState::Ready
            {
                ready_at[idx] = Some(vc.now());
            }
            if in_hostfile_at[idx].is_none() {
                let node = vhpc::cluster::node_name(idx, n + 1);
                // the hostfile lists IPs; resolve via catalog entry
                if let Some(hf) = vc.state.head.hostfile() {
                    let listed = vhpc::consul::catalog::Catalog::list(vc.state.consul.kv(), "hpc")
                        .iter()
                        .any(|e| e.node == node && hf.hosts.iter().any(|h| h.addr == e.address));
                    if listed {
                        in_hostfile_at[idx] = Some(vc.now());
                    }
                }
            }
        }
        if (1..=n as usize).all(|i| in_hostfile_at[i].is_some()) {
            break;
        }
    }
    let delays: Vec<f64> = (1..=n as usize)
        .map(|i| {
            let r = ready_at[i].expect("node never ready");
            let h = in_hostfile_at[i].expect("node never in hostfile");
            h.saturating_sub(r).as_secs_f64()
        })
        .collect();
    let full_cluster = in_hostfile_at[1..=n as usize]
        .iter()
        .map(|t| t.unwrap().as_secs_f64())
        .fold(0.0, f64::max);
    (delays, full_cluster)
}

fn main() {
    banner("Fig. 5 — time from container-ready to hostfile update");
    const MANUAL_PER_NODE_S: f64 = 30.0;
    let mut rows = Vec::new();
    for n in [2u32, 4, 8, 16, 32] {
        let (delays, _) = measure(n);
        let mean = delays.iter().sum::<f64>() / delays.len() as f64;
        let worst = delays.iter().fold(0.0f64, |a, &b| a.max(b));
        let manual = MANUAL_PER_NODE_S * n as f64;
        rows.push(vec![
            n.to_string(),
            format!("{:.0}ms", mean * 1e3),
            format!("{:.0}ms", worst * 1e3),
            format!("{manual:.0}s"),
            format!("{:.0}x", manual / mean.max(0.01)),
        ]);
        // consul's per-node delay must not scale with N: it is bounded
        // by raft commit + the 200ms template poll, regardless of N
        assert!(worst < 1.0, "discovery delay {worst}s too large at n={n}");
    }
    print_table(
        &["nodes", "consul mean", "consul worst", "manual admin (30s/node)", "speedup"],
        &rows,
    );
    println!("\nfig5_discovery OK (consul flat vs manual linear)");
}
