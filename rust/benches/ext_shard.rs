//! Ext-Shard — partitioned-engine speedup: events/sec vs shard count
//! on a large compute-heavy trace, plus the determinism cross-check.
//!
//! Two sections:
//!  1. wall-clock throughput of the sharded drivers at 1/2/4 shards on
//!     a 33-machine, 96-job trace with a deliberately heavy Jacobi
//!     profile (the per-rank compute is what the shard threads
//!     parallelize — a control-plane-only trace would be sync-bound);
//!  2. the merge contract: every shard count must produce the same
//!     window count and byte-identical counter fingerprints.
//!
//! Emits `BENCH_shard.json` (machine-readable, one record per shard
//! count) so the perf trajectory can be tracked across commits.

use std::time::Instant;
use vhpc::bench::{banner, print_table};
use vhpc::cluster::mix::JobReq;
use vhpc::cluster::{run_sharded_mix, ComputeProfile, ShardOutcome, ShardRunConfig};
use vhpc::cluster::policy::SchedulePolicy;
use vhpc::config::ClusterSpec;
use vhpc::sim::SimTime;

const MACHINES: u32 = 33; // head + 32 compute nodes
const JOBS: usize = 96;
const GRID: usize = 128;
const SWEEPS: u32 = 8;
/// Timed repeats per shard count; the minimum wall time is reported
/// (virtual-time results are identical across repeats by construction).
const REPEATS: usize = 2;

fn big_spec() -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = MACHINES;
    spec.machine_spec.boot_time = SimTime::from_secs(5);
    spec.autoscale.min_nodes = MACHINES - 1;
    spec.autoscale.max_nodes = MACHINES - 1;
    spec.autoscale.interval = SimTime::from_secs(5);
    spec.autoscale.cooldown = SimTime::from_secs(10);
    spec.autoscale.idle_timeout = SimTime::from_secs(600);
    spec.seed = 42;
    spec
}

/// Mostly-narrow jobs so work spreads across every shard instead of
/// serializing behind a handful of wide reservations.
fn big_trace() -> Vec<JobReq> {
    let pattern: [(u32, u64); 8] =
        [(8, 60), (4, 45), (8, 90), (2, 30), (8, 75), (4, 60), (16, 90), (8, 45)];
    (0..JOBS)
        .map(|i| {
            let (ranks, secs) = pattern[i % pattern.len()];
            JobReq { ranks, secs, priority: if i % 5 == 0 { 2 } else { 0 } }
        })
        .collect()
}

fn run(shards: usize, jobs: &[JobReq]) -> (ShardOutcome, f64) {
    let cfg = ShardRunConfig {
        shards,
        warmup_slots: (MACHINES - 1) * 12,
        deadline_secs: 3600,
        compute: ComputeProfile { grid: GRID, sweeps_per_tick: SWEEPS },
        ..ShardRunConfig::default()
    };
    let mut best: Option<(ShardOutcome, f64)> = None;
    for _ in 0..REPEATS {
        let t0 = Instant::now();
        let o = run_sharded_mix(big_spec(), jobs, SchedulePolicy::default(), &cfg)
            .expect("sharded mix must drain");
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        if best.as_ref().map_or(true, |(_, b)| dt < *b) {
            best = Some((o, dt));
        }
    }
    best.expect("REPEATS >= 1")
}

fn main() {
    banner(&format!(
        "Ext-Shard1 — events/sec vs shard count ({MACHINES} machines, {JOBS} jobs, \
         {GRID}x{GRID} Jacobi x{SWEEPS}/tick)"
    ));
    let jobs = big_trace();
    let shard_counts = [1usize, 2, 4];
    let mut results: Vec<(usize, ShardOutcome, f64)> = Vec::new();
    for &s in &shard_counts {
        let (o, dt) = run(s, &jobs);
        results.push((s, o, dt));
    }
    let base_rate = {
        let (_, o, dt) = &results[0];
        o.events as f64 / dt
    };
    let mut rows = Vec::new();
    for (s, o, dt) in &results {
        let rate = o.events as f64 / dt;
        rows.push(vec![
            s.to_string(),
            o.windows.to_string(),
            o.events.to_string(),
            format!("{:.2}s", dt),
            format!("{:.0}k ev/s", rate / 1e3),
            format!("{:.2}x", rate / base_rate),
        ]);
    }
    print_table(&["shards", "windows", "events", "wall", "throughput", "speedup"], &rows);

    banner("Ext-Shard2 — merge contract: identical fingerprints at every shard count");
    let (_, base, _) = &results[0];
    assert_eq!(base.jobs_completed as usize, JOBS, "1-shard run must drain the trace");
    for (s, o, _) in &results[1..] {
        assert_eq!(o.windows, base.windows, "{s} shards changed the drain window");
        assert_eq!(
            o.fingerprint, base.fingerprint,
            "{s}-shard fingerprint diverged from the 1-shard run"
        );
    }
    println!("fingerprints byte-identical at shards 1/2/4 ({} counters)", base.fingerprint.len());

    // machine-readable trajectory record; hand-rolled JSON (no serde in
    // the offline crate set)
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"ext_shard\",\n");
    json.push_str(&format!("  \"machines\": {MACHINES},\n"));
    json.push_str(&format!("  \"jobs\": {JOBS},\n"));
    json.push_str(&format!("  \"grid\": {GRID},\n"));
    json.push_str(&format!("  \"sweeps_per_tick\": {SWEEPS},\n"));
    json.push_str("  \"results\": [\n");
    for (i, (s, o, dt)) in results.iter().enumerate() {
        let rate = o.events as f64 / dt;
        json.push_str(&format!(
            "    {{\"shards\": {}, \"windows\": {}, \"events\": {}, \"wall_secs\": {:.4}, \
             \"events_per_sec\": {:.0}, \"speedup\": {:.3}}}{}\n",
            s,
            o.windows,
            o.events,
            dt,
            rate,
            rate / base_rate,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_shard.json", &json).expect("write BENCH_shard.json");
    println!("wrote BENCH_shard.json");

    let (_, o4, dt4) = results.last().expect("4-shard result");
    let speedup = (o4.events as f64 / dt4) / base_rate;
    assert!(
        speedup > 1.5,
        "4 shards must beat 1.5x the single-shard event rate, got {speedup:.2}x"
    );

    println!("\next_shard OK ({speedup:.2}x events/sec at 4 shards, deterministic merge)");
}
