//! Table II — software stack.
//!
//! The paper's stack and the subsystem of this repository that stands in
//! for each component (the substitution table of DESIGN.md, as a bench
//! artifact), with a live smoke-check that each subsystem is wired up.

use vhpc::bench::{banner, print_table};
use vhpc::config::ClusterSpec;
use vhpc::consul::ConsulCluster;
use vhpc::dockyard::{Dockerfile, ImageStore};
use vhpc::sim::SimTime;

fn main() {
    banner("Table II — software stack (paper -> this repo)");
    let rows = vec![
        vec![
            "Physical machine OS".into(),
            "CentOS 7.1.1503 x64".into(),
            "hw::Machine power/boot model".into(),
        ],
        vec![
            "Docker Engine".into(),
            "1.5.0-dev build fc0329b/1.5.0".into(),
            "dockyard::engine (images, layers, lifecycle, cgroups)".into(),
        ],
        vec![
            "Consul".into(),
            "v0.5.2".into(),
            "consul::{gossip SWIM, raft, kv, catalog, health}".into(),
        ],
        vec![
            "Container OS".into(),
            "CentOS 6.7".into(),
            "dockyard base image centos:6".into(),
        ],
        vec![
            "MPI Library".into(),
            "OpenMPI (CentOS 6.7)".into(),
            "mpi::{comm, collectives, mpirun} + PJRT compute".into(),
        ],
        vec![
            "consul-template".into(),
            "(hashicorp project)".into(),
            "consul::template (watch + render)".into(),
        ],
    ];
    print_table(&["component", "paper Table II", "this repository"], &rows);

    banner("live smoke checks");
    // each stack component actually functions:
    let spec = ClusterSpec::paper_testbed();
    assert_eq!(spec.consul_servers, 3);

    let df = Dockerfile::parse(Dockerfile::paper_compute_node()).unwrap();
    let mut store = ImageStore::with_base_images();
    let img = store.build(&df, spec.image.clone()).unwrap();
    println!("dockyard: built {} ({} layers)", img.reference, img.layers.len());

    let mut consul = ConsulCluster::new(3, 42);
    let t = consul.advance_until_leader(SimTime::from_secs(30)).unwrap();
    println!("consul:   3-server raft quorum elected a leader in {t}");

    println!("mpi:      tree depth for 16 ranks = {}", vhpc::mpi::collectives::tree_depth(16));
    println!("\ntable2_software OK");
}
