//! Fig. 3 — cross-host container communication: docker0 (NAT) vs the
//! paper's customized bridge0, plus host networking as the upper bound.
//!
//! Regenerates the figure's motivation as numbers: a ping-pong sweep of
//! message sizes between containers on different blades, per bridge
//! mode. Expected shape: bridge0 ≈ host ≫ docker0, with the NAT gap
//! growing with message size.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use vhpc::bench::{banner, print_table};
use vhpc::hw::rack::Plant;
use vhpc::mpi::hostfile::Hostfile;
use vhpc::mpi::launcher::LaunchPlan;
use vhpc::util::ids::{ContainerId, MachineId};
use vhpc::vnet::addr::Ipv4;
use vhpc::vnet::bridge::BridgeMode;
use vhpc::vnet::fabric::Fabric;
use vhpc::workloads::ring::ping_pong;

fn plan(mode: BridgeMode) -> LaunchPlan {
    let plant = Plant::paper_testbed();
    let mut fabric = Fabric::from_plant(&plant, mode);
    let c0 = ContainerId::new(0);
    let c1 = ContainerId::new(1);
    fabric.place(c0, MachineId::new(1));
    fabric.place(c1, MachineId::new(2));
    let mut ip_to_container = HashMap::new();
    ip_to_container.insert(Ipv4::parse("10.10.0.2").unwrap(), c0);
    ip_to_container.insert(Ipv4::parse("10.10.0.3").unwrap(), c1);
    LaunchPlan {
        hostfile: Hostfile::parse("10.10.0.2 slots=1\n10.10.0.3 slots=1\n").unwrap(),
        n_ranks: 2,
        ip_to_container,
        fabric: Arc::new(Mutex::new(fabric)),
        eager_threshold: 64 * 1024,
    }
}

fn main() {
    let sizes: Vec<usize> =
        vec![64, 1024, 4096, 16 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20, 16 << 20, 64 << 20];
    let modes = [BridgeMode::Docker0, BridgeMode::Bridge0, BridgeMode::Host];

    let mut results: HashMap<&str, Vec<vhpc::workloads::ring::PingPongPoint>> = HashMap::new();
    for mode in modes {
        let p = plan(mode);
        results.insert(mode.name(), ping_pong(&p, &sizes, 8).unwrap());
    }

    banner("Fig. 3 — one-way latency by bridge mode (cross-host)");
    let mut rows = Vec::new();
    for (i, &bytes) in sizes.iter().enumerate() {
        rows.push(vec![
            format!("{bytes}"),
            results["docker0"][i].one_way.to_string(),
            results["bridge0"][i].one_way.to_string(),
            results["host"][i].one_way.to_string(),
            format!(
                "{:.2}x",
                results["docker0"][i].one_way.as_nanos() as f64
                    / results["bridge0"][i].one_way.as_nanos() as f64
            ),
        ]);
    }
    print_table(&["bytes", "docker0(NAT)", "bridge0", "host", "NAT penalty"], &rows);

    banner("Fig. 3 — effective bandwidth (MB/s)");
    let mut rows = Vec::new();
    for (i, &bytes) in sizes.iter().enumerate() {
        rows.push(vec![
            format!("{bytes}"),
            format!("{:.1}", results["docker0"][i].bandwidth / 1e6),
            format!("{:.1}", results["bridge0"][i].bandwidth / 1e6),
            format!("{:.1}", results["host"][i].bandwidth / 1e6),
        ]);
    }
    print_table(&["bytes", "docker0(NAT)", "bridge0", "host"], &rows);

    // shape assertions
    for i in 0..sizes.len() {
        assert!(
            results["docker0"][i].one_way > results["bridge0"][i].one_way,
            "NAT must be slower at every size"
        );
        assert!(results["bridge0"][i].one_way >= results["host"][i].one_way);
    }
    let small_gap = results["docker0"][0].one_way.as_nanos() - results["bridge0"][0].one_way.as_nanos();
    let large_gap = results["docker0"][sizes.len() - 1].one_way.as_nanos()
        - results["bridge0"][sizes.len() - 1].one_way.as_nanos();
    assert!(large_gap > small_gap, "NAT gap must grow with size");
    // bridge0 approaches 10GbE line rate on big transfers
    let line = 10e9 / 8.0;
    let last = &results["bridge0"][sizes.len() - 1];
    assert!(last.bandwidth / line > 0.8, "bridge0 bw {:.0} too low", last.bandwidth);
    println!("\nfig3_bridge_vs_nat OK (bridge0 ~ host >> docker0, gap grows with size)");
}
