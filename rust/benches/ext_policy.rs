//! Ext-P — scheduling policy comparison: wait time, utilization and
//! rack spread under FIFO (conservative backfill), EASY backfill,
//! priority scheduling with preemption, and topology-aware placement.
//!
//! Three deterministic scenarios on fixed-size clusters (autoscaling
//! off so every difference is the policy's doing):
//!
//! * **P1 — EASY vs FIFO.** A long wide job plus a blocked full-width
//!   head job, trailed by short narrow jobs. The conservative guard
//!   refuses every short job (head width + backfill would exceed the
//!   cluster), so they all wait out the head; EASY proves from the
//!   known runtimes that they finish before the head's reservation and
//!   runs them in the spare slots — mean wait and makespan both drop.
//! * **P2 — topology-aware vs width-only placement.** A 3-rack
//!   cluster with a completion pattern that fragments the free pool.
//!   Width-only carving picks hosts in hostfile order and spans a rack
//!   boundary where a whole rack was available; rack packing keeps the
//!   job inside one rack, cutting mean rack spread.
//! * **P3 — priority vs FIFO.** An urgent job submitted behind a wall
//!   of batch work: FIFO makes it wait the wall out, the priority
//!   policy runs it first; plus a preemption walkthrough where the
//!   urgent arrival checkpoints-and-requeues running batch work.
//!
//! Every scenario is replayed to check same-seed determinism.

use vhpc::bench::{banner, print_table};
use vhpc::cluster::head::JobKind;
use vhpc::cluster::mix::{run_policy_trace, JobReq, TraceOutcome};
use vhpc::cluster::policy::SchedulePolicy;
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::cluster::JobState;
use vhpc::config::ClusterSpec;
use vhpc::sim::SimTime;
use std::collections::BTreeMap;

/// Fixed-size cluster: `machines - 1` compute nodes all provisioned at
/// start, autoscaling off, spread over `racks` racks (0 = one chassis).
fn fixed_spec(machines: u32, racks: u32) -> ClusterSpec {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = machines;
    spec.racks = racks;
    spec.machine_spec.boot_time = SimTime::from_secs(10);
    spec.autoscale.enabled = false;
    spec.autoscale.min_nodes = machines - 1;
    spec.autoscale.max_nodes = machines - 1;
    spec
}

fn req(ranks: u32, secs: u64) -> JobReq {
    JobReq { ranks, secs, priority: 0 }
}

/// Useful slot-seconds in the trace divided by makespan x capacity.
fn utilization(trace: &[JobReq], outcome: &TraceOutcome, slots: u32) -> f64 {
    let useful: f64 = trace.iter().map(|j| j.ranks as f64 * j.secs as f64).sum();
    useful / (outcome.makespan.max(1e-9) * slots as f64)
}

fn policy_row(name: &str, trace: &[JobReq], o: &TraceOutcome, slots: u32) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{:.1}s", o.mean_wait),
        format!("{:.1}s", o.max_wait),
        format!("{:.0}s", o.makespan),
        format!("{:.0}%", 100.0 * utilization(trace, o, slots)),
        o.backfill_starts.to_string(),
        o.preemptions.to_string(),
        format!("{:.2}", o.mean_rack_spread),
    ]
}

const HEADERS: [&str; 8] = [
    "policy",
    "mean wait",
    "max wait",
    "makespan",
    "util",
    "backfills",
    "preempts",
    "rack spread",
];

fn run(
    machines: u32,
    racks: u32,
    trace: &[JobReq],
    policy: SchedulePolicy,
) -> (TraceOutcome, BTreeMap<String, u64>) {
    let spec = fixed_spec(machines, racks);
    let warmup = (machines - 1) * spec.slots_per_node;
    let (outcome, vc) = run_policy_trace(spec, trace, policy, usize::MAX, warmup, 3600)
        .expect("policy trace must drain");
    (outcome, vc.metrics().counters_snapshot())
}

fn main() {
    // ---- P1: EASY vs FIFO on a blocked-head trace (3 nodes, 36 slots)
    banner("Ext-P1 — EASY vs FIFO backfill (4 machines, 36 slots)");
    let mut trace = vec![req(24, 240), req(36, 60)];
    trace.extend(std::iter::repeat(req(8, 30)).take(8));
    let (fifo, _) = run(4, 0, &trace, SchedulePolicy::fifo());
    let (easy, easy_fp) = run(4, 0, &trace, SchedulePolicy::easy());
    print_table(
        &HEADERS,
        &[
            policy_row("fifo", &trace, &fifo, 36),
            policy_row("easy", &trace, &easy, 36),
        ],
    );
    assert_eq!(fifo.backfill_starts, 0, "the conservative guard must refuse all shorts");
    assert!(
        easy.backfill_starts >= 6,
        "EASY must backfill the short jobs: {}",
        easy.backfill_starts
    );
    assert!(
        easy.mean_wait < fifo.mean_wait,
        "EASY must cut mean wait: easy {:.1}s vs fifo {:.1}s",
        easy.mean_wait,
        fifo.mean_wait
    );
    assert!(easy.makespan <= fifo.makespan, "EASY must not stretch the makespan");
    assert_eq!(fifo.preemptions + easy.preemptions, 0);

    // ---- P2: topology-aware vs width-only placement (8 nodes, 3 racks)
    banner("Ext-P2 — topology-aware vs width-only placement (9 machines, 3 racks)");
    // rack0 = {node02,node03}, rack1 = {node04..node06}, rack2 =
    // {node07..node09}. The first three jobs are rack-shaped (identical
    // placement in both modes); completions then leave a fragmented
    // pool where only rack packing keeps job 4 inside one rack.
    let topo_trace = vec![
        req(24, 300), // rack0 for the whole scenario
        req(36, 60),  // rack1, frees at t=60
        req(36, 120), // rack2, frees at t=120
        req(24, 120), // starts at 60 on the first two rack1 nodes
        req(24, 60),  // the discriminator: dispatched at t=120
        req(12, 30),  // backfills the last rack1 node at t=60
    ];
    let (width, _) = run(9, 3, &topo_trace, SchedulePolicy::fifo());
    let (topo, topo_fp) = run(9, 3, &topo_trace, SchedulePolicy::fifo().with_topo_aware(true));
    print_table(
        &HEADERS,
        &[
            policy_row("width-only", &topo_trace, &width, 96),
            policy_row("topo-aware", &topo_trace, &topo, 96),
        ],
    );
    assert!(
        topo.mean_rack_spread < width.mean_rack_spread,
        "rack packing must cut mean rack spread: topo {:.2} vs width {:.2}",
        topo.mean_rack_spread,
        width.mean_rack_spread
    );
    assert!(
        (topo.makespan - width.makespan).abs() < 2.0,
        "placement flavor must not change the schedule: {} vs {}",
        topo.makespan,
        width.makespan
    );

    // ---- P3: priority vs FIFO, plus a preemption walkthrough
    banner("Ext-P3 — priority scheduling (4 machines, 36 slots)");
    let pri_trace = vec![
        JobReq { ranks: 36, secs: 60, priority: 0 },
        JobReq { ranks: 36, secs: 60, priority: 0 },
        JobReq { ranks: 36, secs: 60, priority: 0 },
        JobReq { ranks: 24, secs: 30, priority: 5 },
    ];
    let urgent_wait = |vc: &VirtualCluster| -> f64 {
        vc.completed_jobs()
            .iter()
            .filter(|r| r.spec.priority > 0)
            .map(|r| match r.state {
                JobState::Done { started, .. } => {
                    started.saturating_sub(r.queued_at).as_secs_f64()
                }
                _ => f64::INFINITY,
            })
            .fold(0.0, f64::max)
    };
    let spec = fixed_spec(4, 0);
    let (fifo_o, fifo_vc) =
        run_policy_trace(spec.clone(), &pri_trace, SchedulePolicy::fifo(), usize::MAX, 36, 3600)
            .expect("fifo priority trace");
    let (pri_o, pri_vc) =
        run_policy_trace(spec, &pri_trace, SchedulePolicy::priority(), usize::MAX, 36, 3600)
            .expect("priority trace");
    let fifo_urgent = urgent_wait(&fifo_vc);
    let pri_urgent = urgent_wait(&pri_vc);
    print_table(
        &HEADERS,
        &[
            policy_row("fifo", &pri_trace, &fifo_o, 36),
            policy_row("priority", &pri_trace, &pri_o, 36),
        ],
    );
    println!("urgent-job wait: fifo {fifo_urgent:.1}s vs priority {pri_urgent:.1}s");
    assert!(
        pri_urgent < fifo_urgent,
        "the priority policy must run urgent work sooner ({pri_urgent:.1}s vs {fifo_urgent:.1}s)"
    );
    assert!(fifo_urgent > 100.0, "under FIFO the urgent job waits out the batch wall");

    // preemption walkthrough: urgent work arrives mid-run
    let mut vc = VirtualCluster::new(fixed_spec(3, 0)).expect("cluster");
    vc.state.head.policy = SchedulePolicy::priority();
    vc.start();
    assert!(vc.advance_until(SimTime::from_secs(600), |st| st.head.slots_available() >= 24));
    vc.submit("batch", 24, JobKind::Synthetic { duration: SimTime::from_secs(300) });
    assert!(vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 1));
    let t_submit = vc.now();
    vc.submit_with_priority(
        "urgent",
        24,
        JobKind::Synthetic { duration: SimTime::from_secs(30) },
        5,
    );
    assert!(
        vc.advance_until(SimTime::from_secs(120), |st| {
            st.head.completed.iter().any(|r| r.spec.name == "urgent")
        }),
        "urgent job must preempt its way in"
    );
    let preempt_latency = vc.now().saturating_sub(t_submit).as_secs_f64() - 30.0;
    assert_eq!(vc.metrics().counter("jobs_preempted"), 1, "exactly one preemption");
    assert!(vc.advance_until(SimTime::from_secs(900), |st| st.head.completed.len() == 2));
    println!(
        "preemption: urgent 24-rank job started within {preempt_latency:.0}s of submit; \
         batch job requeued with credit and finished after"
    );

    // ---- determinism: same seed, same schedule, byte for byte
    banner("Ext-P4 — same seed, same schedule (determinism)");
    let (_, easy_fp2) = run(4, 0, &trace, SchedulePolicy::easy());
    let (_, topo_fp2) = run(9, 3, &topo_trace, SchedulePolicy::fifo().with_topo_aware(true));
    assert_eq!(easy_fp, easy_fp2, "EASY replay diverged");
    assert_eq!(topo_fp, topo_fp2, "topology-aware replay diverged");
    println!(
        "EASY and topo-aware replays identical ({} / {} counters)",
        easy_fp.len(),
        topo_fp.len()
    );

    println!("\next_policy OK (EASY cuts waits, rack packing cuts spread, priority preempts, deterministic)");
}
