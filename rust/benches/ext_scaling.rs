//! Ext-A — strong scaling of the Jacobi job (the interconnect
//! performance study the paper's conclusion promises).
//!
//! Fixed 256×256 global grid, rank counts 1/4/16/64 (tiles 256/128/64/32
//! — all shipped artifacts), bridge0 vs docker0.
//!
//! Time model: communication is the *virtual* fabric time actually
//! charged by the MPI layer during the real run. Compute is *modeled*
//! at a calibrated stencil rate for the testbed CPU (Xeon E5-2630,
//! ~2 GFLOP/s effective per core on a memory-bound 5-point stencil) —
//! the interpret-mode Pallas wall-clock is NOT a proxy for testbed
//! compute (per-call interpreter overhead dominates; see DESIGN.md
//! §Perf), so it is reported only as a reference column.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use vhpc::bench::{banner, print_table};
use vhpc::hw::rack::Plant;
use vhpc::hw::MachineSpec;
use vhpc::mpi::hostfile::Hostfile;
use vhpc::mpi::launcher::LaunchPlan;
use vhpc::runtime::Runtime;
use vhpc::util::ids::{ContainerId, MachineId};
use vhpc::vnet::addr::Ipv4;
use vhpc::vnet::bridge::BridgeMode;
use vhpc::vnet::fabric::Fabric;
use vhpc::workloads::jacobi::{run_jacobi, JacobiSpec};

/// Effective stencil rate per core (flops/sec): 5-point Jacobi is
/// memory-bound; ~2 GFLOP/s on a 2.3 GHz Sandy Bridge core.
const STENCIL_FLOPS_PER_SEC: f64 = 2.0e9;
/// flops per cell per step (3 adds + 1 mul + residual 2).
const FLOPS_PER_CELL: f64 = 6.0;

fn plan(mode: BridgeMode, n_ranks: usize) -> LaunchPlan {
    let plant = Plant::uniform(3, MachineSpec::dell_m620(), 3);
    let mut fabric = Fabric::from_plant(&plant, mode);
    let mut ip_to_container = HashMap::new();
    let mut hf = String::new();
    let slots = n_ranks.div_ceil(3).max(1);
    for i in 0..3u32 {
        let c = ContainerId::new(i);
        fabric.place(c, MachineId::new(i));
        let ip = Ipv4::new(10, 10, 0, (i + 2) as u8);
        ip_to_container.insert(ip, c);
        hf.push_str(&format!("{ip} slots={slots}\n"));
    }
    LaunchPlan {
        hostfile: Hostfile::parse(&hf).unwrap(),
        n_ranks,
        ip_to_container,
        fabric: Arc::new(Mutex::new(fabric)),
        eager_threshold: 64 * 1024,
    }
}

fn main() {
    banner("Ext-A — strong scaling, 256x256 grid, 100 steps");
    let configs = [(1usize, 1usize, 256usize), (2, 2, 128), (4, 4, 64), (8, 8, 32)];
    let steps = 100;
    let mut rows = Vec::new();
    let mut shares: HashMap<usize, f64> = HashMap::new();
    let mut totals: HashMap<(usize, &str), f64> = HashMap::new();
    for &(px, py, tile) in &configs {
        let n = px * py;
        let spec = JacobiSpec {
            px,
            py,
            tile,
            steps,
            check_every: steps,
            tol: 0.0,
            artifacts: Runtime::default_dir(),
        };
        let rb = run_jacobi(&plan(BridgeMode::Bridge0, n), &spec).unwrap();
        let rn = run_jacobi(&plan(BridgeMode::Docker0, n), &spec).unwrap();
        // modeled compute: per-rank tile work per step, perfectly parallel
        let compute = (tile * tile) as f64 * FLOPS_PER_CELL * steps as f64 / STENCIL_FLOPS_PER_SEC;
        let comm_b = rb.comm_time.as_secs_f64();
        let comm_n = rn.comm_time.as_secs_f64();
        let total_b = compute + comm_b;
        let total_n = compute + comm_n;
        shares.insert(n, comm_b / total_b);
        totals.insert((n, "b"), total_b);
        totals.insert((n, "n"), total_n);
        rows.push(vec![
            n.to_string(),
            format!("{tile}^2"),
            format!("{:.1}ms", compute * 1e3),
            format!("{:.2}ms", comm_b * 1e3),
            format!("{:.1}%", 100.0 * comm_b / total_b),
            format!("{:.2}x", totals[&(1, "b")] / total_b),
            format!("{:.2}ms", comm_n * 1e3),
            format!("{:.1}%", 100.0 * comm_n / total_n),
            format!("{:.3}s", rb.compute_wall_max.as_secs_f64()),
        ]);
    }
    print_table(
        &[
            "ranks",
            "tile",
            "compute*",
            "comm(b0)",
            "share",
            "speedup",
            "comm(d0)",
            "share",
            "interp wall(ref)",
        ],
        &rows,
    );
    println!("* modeled at {:.1} GFLOP/s/core effective stencil rate", STENCIL_FLOPS_PER_SEC / 1e9);

    // strong-scaling shape: comm share rises as ranks grow
    assert!(shares[&64] > shares[&4], "comm share must grow: {shares:?}");
    assert!(shares[&16] > shares[&1], "comm share must grow: {shares:?}");
    // docker0 pays more total time than bridge0 at every scale
    for &(px, py, _) in &configs[1..] {
        let n = px * py;
        assert!(totals[&(n, "n")] > totals[&(n, "b")], "docker0 must cost more at n={n}");
    }
    println!("\next_scaling OK (comm share rises with ranks; docker0 pays more)");
}
