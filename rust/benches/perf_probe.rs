//! §Perf probe — decomposes the MPI+PJRT hot path so the optimization
//! loop (EXPERIMENTS.md §Perf) has numbers to chase.
//!
//! Phases measured:
//!   p2p     — real wall time per send+recv pair (256 B eager message)
//!   halo    — per-step halo pack/exchange/unpack for a 64² tile
//!   pjrt    — per-step jacobi_step PJRT execution (interpret mode)
//!   e2e     — full 16-rank × 50-step job wall vs sum of parts

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;
use vhpc::bench::{banner, print_table, time};
use vhpc::hw::rack::Plant;
use vhpc::mpi::comm::MpiWorldBuilder;
use vhpc::mpi::hostfile::Hostfile;
use vhpc::mpi::launcher::LaunchPlan;
use vhpc::runtime::Runtime;
use vhpc::util::ids::{ContainerId, MachineId};
use vhpc::vnet::addr::Ipv4;
use vhpc::vnet::bridge::BridgeMode;
use vhpc::vnet::fabric::Fabric;
use vhpc::workloads::jacobi::{run_jacobi, JacobiSpec};

fn fabric_pair() -> Arc<Mutex<Fabric>> {
    let plant = Plant::paper_testbed();
    let mut fabric = Fabric::from_plant(&plant, BridgeMode::Bridge0);
    fabric.place(ContainerId::new(0), MachineId::new(1));
    fabric.place(ContainerId::new(1), MachineId::new(2));
    Arc::new(Mutex::new(fabric))
}

fn main() {
    banner("perf probe — L3 hot-path decomposition");
    let mut rows = Vec::new();

    // --- p2p message overhead (real wall time of the machinery) ---
    {
        let comms = MpiWorldBuilder::new(2).fabric(fabric_pair()).build();
        let mut it = comms.into_iter();
        let mut c0 = it.next().unwrap();
        let mut c1 = it.next().unwrap();
        let payload = vec![0u8; 256];
        let h = std::thread::spawn(move || {
            for i in 0..20_000u64 {
                c1.recv(0, i);
            }
            c1.stats.clone()
        });
        let n = 20_000u64;
        let t0 = Instant::now();
        for i in 0..n {
            c0.send(1, i, &payload);
        }
        let send_side = t0.elapsed();
        h.join().unwrap();
        rows.push(vec![
            "send(256B) wall".into(),
            format!("{:.0}ns/msg", send_side.as_nanos() as f64 / n as f64),
        ]);
    }

    // --- PJRT step cost (the L1/L2 kernel through the runtime) ---
    {
        let rt = Runtime::load(Runtime::default_dir()).expect("artifacts");
        let padded = vec![1.0f32; 66 * 66];
        rt.jacobi_step("jacobi_step_64", &padded).unwrap(); // compile
        let s = time(3, 50, || {
            rt.jacobi_step("jacobi_step_64", &padded).unwrap();
        });
        rows.push(vec![
            "pjrt jacobi_step_64".into(),
            format!("{:.2}ms/step", s.mean.as_secs_f64() * 1e3),
        ]);
        // the fused-sweep artifact amortizes dispatch: 100 steps/call
        let s = time(1, 5, || {
            rt.jacobi_sweep("jacobi_sweep_128_k100", &vec![1.0f32; 130 * 130])
                .unwrap();
        });
        rows.push(vec![
            "pjrt jacobi_sweep_128_k100".into(),
            format!("{:.3}ms/step (fused)", s.mean.as_secs_f64() * 1e3 / 100.0),
        ]);
    }

    // --- end-to-end 16-rank job ---
    {
        let mut ip_to_container = HashMap::new();
        let plant = Plant::paper_testbed();
        let mut fabric = Fabric::from_plant(&plant, BridgeMode::Bridge0);
        for i in 0..2u32 {
            let c = ContainerId::new(i);
            fabric.place(c, MachineId::new(i + 1));
            ip_to_container.insert(Ipv4::new(10, 10, 0, (i + 2) as u8), c);
        }
        let plan = LaunchPlan {
            hostfile: Hostfile::parse("10.10.0.2 slots=12\n10.10.0.3 slots=12\n").unwrap(),
            n_ranks: 16,
            ip_to_container,
            fabric: Arc::new(Mutex::new(fabric)),
            eager_threshold: 64 * 1024,
        };
        let spec = JacobiSpec {
            px: 4,
            py: 4,
            tile: 64,
            steps: 50,
            check_every: 50,
            tol: 0.0,
            artifacts: Runtime::default_dir(),
        };
        let report = run_jacobi(&plan, &spec).unwrap();
        let wall = report.wall.as_secs_f64();
        let compute = report.compute_wall_max.as_secs_f64();
        rows.push(vec!["e2e 16r x 50 steps wall".into(), format!("{wall:.3}s")]);
        rows.push(vec!["  compute (max rank)".into(), format!("{compute:.3}s")]);
        rows.push(vec![
            "  L3 overhead (wall - compute)".into(),
            format!("{:.3}s ({:.0}%)", wall - compute, 100.0 * (wall - compute) / wall),
        ]);
        rows.push(vec![
            "  msgs / bytes".into(),
            format!("{} / {}", report.total_msgs, vhpc::util::format_bytes(report.total_bytes)),
        ]);
    }
    print_table(&["phase", "cost"], &rows);
    println!("\nperf_probe done");
}
