//! Table I — hardware spec of the testbed.
//!
//! Regenerates the paper's Table I from the modeled `MachineSpec` and
//! asserts the model matches the published numbers.

use vhpc::bench::{banner, print_table};
use vhpc::hw::rack::Plant;
use vhpc::hw::MachineSpec;
use vhpc::util::format_bytes;

fn main() {
    banner("Table I — physical machine specification (modeled)");
    let spec = MachineSpec::dell_m620();
    let rows = vec![
        vec!["System Model".into(), spec.model.clone(), "Dell M620".into()],
        vec![
            "CPU".into(),
            format!(
                "Intel Xeon E5-2630 {:.2}GHz x {} ({} cores)",
                spec.clock_ghz,
                spec.sockets,
                spec.total_cores()
            ),
            "Intel(R) Xeon E5-2630 2.30GHz X 2".into(),
        ],
        vec!["Memory".into(), format_bytes(spec.memory_bytes), "64GB".into()],
        vec![
            "HDD".into(),
            format!("SAS {} 10Krpm", format_bytes(spec.disk_bytes)),
            "SAS 146GB 10Krpm".into(),
        ],
        vec!["Network".into(), spec.nic.name.into(), "10GbE".into()],
        vec![
            "Boot time (modeled)".into(),
            spec.boot_time.to_string(),
            "(not reported)".into(),
        ],
    ];
    print_table(&["field", "modeled", "paper Table I"], &rows);

    // assertions: the model must agree with the paper
    assert_eq!(spec.model, "Dell M620");
    assert_eq!(spec.clock_ghz, 2.30);
    assert_eq!(spec.sockets, 2);
    assert_eq!(spec.memory_bytes, 64 << 30);
    assert_eq!(spec.disk_bytes, 146 << 30);
    assert_eq!(spec.nic.name, "10GbE");

    banner("testbed topology (Fig. 4)");
    let plant = Plant::paper_testbed();
    let rows: Vec<Vec<String>> = plant
        .machines
        .iter()
        .map(|m| {
            vec![
                m.hostname.clone(),
                m.spec.model.clone(),
                format!("{} cores", m.spec.total_cores()),
                format_bytes(m.spec.memory_bytes),
                m.spec.nic.name.to_string(),
            ]
        })
        .collect();
    print_table(&["host", "model", "cpu", "memory", "nic"], &rows);
    assert_eq!(plant.machines.len(), 3);
    println!("\ntable1_hardware OK");
}
