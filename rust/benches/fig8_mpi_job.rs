//! Fig. 8 — "Execution of a 16-domain MPI job on the virtual HPC
//! cluster with 2 containers."
//!
//! The paper shows a screenshot of the job running; we regenerate the
//! run itself: 16 Jacobi domains (4×4 of 64² tiles) on 2 containers
//! (12+4 rank placement, the OpenMPI fill order), real Pallas/PJRT
//! compute per rank, and report the residual curve, throughput and the
//! comm/compute split — for the paper's bridge0 and the docker0 baseline.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use vhpc::bench::{banner, print_table};
use vhpc::hw::rack::Plant;
use vhpc::mpi::hostfile::Hostfile;
use vhpc::mpi::launcher::LaunchPlan;
use vhpc::runtime::Runtime;
use vhpc::util::ids::{ContainerId, MachineId};
use vhpc::vnet::addr::Ipv4;
use vhpc::vnet::bridge::BridgeMode;
use vhpc::vnet::fabric::Fabric;
use vhpc::workloads::jacobi::{run_jacobi, serial_jacobi, stitch, JacobiSpec};

fn plan(mode: BridgeMode) -> LaunchPlan {
    let plant = Plant::paper_testbed();
    let mut fabric = Fabric::from_plant(&plant, mode);
    let c0 = ContainerId::new(0);
    let c1 = ContainerId::new(1);
    fabric.place(c0, MachineId::new(1));
    fabric.place(c1, MachineId::new(2));
    let mut ip_to_container = HashMap::new();
    ip_to_container.insert(Ipv4::parse("10.10.0.2").unwrap(), c0);
    ip_to_container.insert(Ipv4::parse("10.10.0.3").unwrap(), c1);
    LaunchPlan {
        hostfile: Hostfile::parse("10.10.0.2 slots=12\n10.10.0.3 slots=12\n").unwrap(),
        n_ranks: 16,
        ip_to_container,
        fabric: Arc::new(Mutex::new(fabric)),
        eager_threshold: 64 * 1024,
    }
}

fn main() {
    let spec = JacobiSpec {
        px: 4,
        py: 4,
        tile: 64,
        steps: 200,
        check_every: 20,
        tol: 0.0,
        artifacts: Runtime::default_dir(),
    };
    banner("Fig. 8 — 16-domain MPI Jacobi on 2 containers (bridge0)");
    let report = run_jacobi(&plan(BridgeMode::Bridge0), &spec).unwrap();

    let rows: Vec<Vec<String>> = report
        .residual_curve
        .iter()
        .map(|(s, r)| vec![s.to_string(), format!("{r:.6e}")])
        .collect();
    print_table(&["step", "global residual^2"], &rows);

    // convergence shape
    for w in report.residual_curve.windows(2) {
        assert!(w[1].1 < w[0].1, "residual must fall monotonically");
    }

    // numerics vs the serial oracle
    let got = stitch(&report.ranks, 4, 4, 64);
    let (want, _) = serial_jacobi(256, 256, report.steps_run);
    let max_err = got.iter().zip(&want).map(|(g, w)| (g - w).abs()).fold(0f32, f32::max);
    assert!(max_err < 1e-4, "distributed != serial: {max_err}");

    banner("job report");
    let nat = run_jacobi(&plan(BridgeMode::Docker0), &spec).unwrap();
    let total_b = report.comm_time.as_secs_f64() + report.compute_wall_max.as_secs_f64();
    let total_n = nat.comm_time.as_secs_f64() + nat.compute_wall_max.as_secs_f64();
    let rows = vec![
        vec![
            "steps".into(),
            report.steps_run.to_string(),
            nat.steps_run.to_string(),
        ],
        vec![
            "compute (max rank)".into(),
            format!("{:.3}s", report.compute_wall_max.as_secs_f64()),
            format!("{:.3}s", nat.compute_wall_max.as_secs_f64()),
        ],
        vec![
            "virtual comm".into(),
            report.comm_time.to_string(),
            nat.comm_time.to_string(),
        ],
        vec![
            "comm+compute".into(),
            format!("{total_b:.3}s"),
            format!("{total_n:.3}s"),
        ],
        vec![
            "steps/s (virtual)".into(),
            format!("{:.1}", report.steps_run as f64 / total_b),
            format!("{:.1}", nat.steps_run as f64 / total_n),
        ],
        vec![
            "MPI traffic".into(),
            vhpc::util::format_bytes(report.total_bytes),
            vhpc::util::format_bytes(nat.total_bytes),
        ],
        vec![
            "max |err| vs serial".into(),
            format!("{max_err:.2e}"),
            "-".into(),
        ],
    ];
    print_table(&["metric", "bridge0 (paper)", "docker0 (baseline)"], &rows);
    assert!(nat.comm_time > report.comm_time, "NAT must cost more comm time");

    // ---- multi-job extension: two 8-rank jobs on disjoint slot slices ----
    // The head's scheduler carves each job a slice of the advertised
    // hostfile; here both slices of the 24-slot file run real Jacobi
    // jobs and must never share a slot.
    banner("two 8-rank jobs on disjoint hostfile slices (concurrent head)");
    use vhpc::cluster::head::{Head, JobKind, JobSpec};
    use vhpc::sim::SimTime;
    use vhpc::util::ids::JobId;
    let mut head = Head::new();
    head.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
    for i in 0..2u32 {
        head.submit(
            JobSpec {
                id: JobId::new(i),
                name: format!("slice-{i}"),
                ranks: 8,
                kind: JobKind::Synthetic { duration: SimTime::from_secs(1) },
                priority: 0,
                tenant: 0,
            },
            SimTime::ZERO,
        );
    }
    let a = head.start_next(SimTime::ZERO).expect("job a starts");
    let b = head.start_next(SimTime::ZERO).expect("job b starts");
    assert_eq!(head.running.len(), 2, "both jobs run concurrently");
    assert_eq!(a.hostfile_slice.total_slots(), 8);
    assert_eq!(b.hostfile_slice.total_slots(), 8);
    assert!(head.overbooked_hosts().is_empty(), "slices must be disjoint");

    let spec8 = JacobiSpec {
        px: 4,
        py: 2,
        tile: 64,
        steps: 100,
        check_every: 20,
        tol: 0.0,
        artifacts: Runtime::default_dir(),
    };
    let mut slice_rows = Vec::new();
    for job in [&a, &b] {
        let mut p = plan(BridgeMode::Bridge0);
        p.hostfile = job.hostfile_slice.clone();
        p.n_ranks = 8;
        let rep = run_jacobi(&p, &spec8).unwrap();
        assert!(rep.final_residual.is_finite() && rep.final_residual > 0.0);
        slice_rows.push(vec![
            job.spec.name.clone(),
            job.hostfile_slice.render().replace('\n', "  ").trim().to_string(),
            rep.steps_run.to_string(),
            format!("{:.3e}", rep.final_residual),
        ]);
    }
    print_table(&["job", "reserved slice", "steps", "final residual^2"], &slice_rows);

    println!(
        "\nfig8_mpi_job OK (converges, matches oracle, bridge0 beats docker0, slices disjoint)"
    );
}
