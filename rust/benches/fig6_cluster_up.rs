//! Fig. 6 — three containers running on three physical machines.
//!
//! Regenerates the screenshot's content as a provisioning timeline:
//! per-machine phase breakdown (boot → dockerd → pull+extract → start →
//! register → in hostfile) for the paper's exact 3-blade deployment,
//! plus the layer-cache effect (second deployment pulls nothing).

use vhpc::bench::{banner, print_table};
use vhpc::cluster::vcluster::{NodeState, VirtualCluster};
use vhpc::config::ClusterSpec;
use vhpc::sim::SimTime;
use vhpc::util::format_bytes;
use vhpc::util::ids::MachineId;

fn main() {
    banner("Fig. 6 — cluster bring-up (3 blades, paper testbed)");
    let spec = ClusterSpec::paper_testbed();
    let boot = spec.machine_spec.boot_time;
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.start();

    // sample state transitions
    let mut transitions: Vec<(SimTime, String)> = Vec::new();
    let mut last: Vec<NodeState> = (0..3).map(|i| vc.node_state(MachineId::new(i))).collect();
    let deadline = SimTime::from_secs(600);
    while vc.now() < deadline {
        vc.advance(SimTime::from_millis(200));
        for i in 0..3u32 {
            let s = vc.node_state(MachineId::new(i));
            if s != last[i as usize] {
                transitions.push((vc.now(), format!("blade{:02} -> {s:?}", i + 1)));
                last[i as usize] = s;
            }
        }
        if vc.state.head.hostfile().map(|h| h.hosts.len()) == Some(2) {
            transitions.push((vc.now(), "hostfile complete (2 nodes)".into()));
            break;
        }
    }
    let rows: Vec<Vec<String>> = transitions
        .iter()
        .map(|(t, what)| vec![t.to_string(), what.clone()])
        .collect();
    print_table(&["t (virtual)", "event"], &rows);

    banner("docker ps per blade (the Fig. 6 screenshots)");
    for (i, eng) in vc.state.engines.iter().enumerate() {
        println!("[blade{:02}] $ docker ps", i + 1);
        print!("{}", eng.format_ps());
    }

    banner("phase budget per machine");
    let m = vc.metrics();
    let pull = m.histogram("pull_seconds").unwrap();
    let prov = m.histogram("provision_seconds").unwrap();
    let rows = vec![
        vec!["power-on -> OS up".into(), boot.to_string()],
        vec!["dockerd start".into(), "2.000s".into()],
        vec![
            "image pull (10GbE)".into(),
            format!("{:.3}s mean", pull.mean()),
        ],
        vec![
            "total provision".into(),
            format!("{:.3}s mean", prov.mean()),
        ],
        vec![
            "bytes pulled (all machines)".into(),
            format_bytes(m.counter("bytes_pulled")),
        ],
    ];
    print_table(&["phase", "time"], &rows);

    assert_eq!(vc.ready_compute_nodes(), 2);
    assert!(prov.mean() > boot.as_secs_f64(), "provision must include boot");
    // provisioning is boot-dominated on the paper's hardware
    assert!(
        prov.mean() < boot.as_secs_f64() + 30.0,
        "non-boot overhead too large: {:.1}s",
        prov.mean()
    );

    banner("warm-cache redeploy (layer dedup)");
    // retire and re-provision machine 2: image already in its store
    let pulls_before = vc.metrics().counter("bytes_pulled");
    vc.kill_machine(MachineId::new(2));
    vc.advance(SimTime::from_secs(5));
    vc.power_on(MachineId::new(2));
    let ok = vc.advance_until(SimTime::from_secs(300), |st| {
        st.node_states[2] == NodeState::Ready
    });
    assert!(ok, "redeploy failed");
    let pulls_after = vc.metrics().counter("bytes_pulled");
    println!(
        "second deploy pulled {} (cold deploy pulled {})",
        format_bytes(pulls_after - pulls_before),
        format_bytes(pulls_before / 3)
    );
    assert_eq!(pulls_after, pulls_before, "warm cache must pull 0 bytes");
    println!("\nfig6_cluster_up OK");
}
