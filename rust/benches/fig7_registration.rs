//! Fig. 7 — containers register themselves to the Consul service.
//!
//! Regenerates the screenshot's content as a scaling study: how long
//! gossip membership takes to converge (every agent sees every other
//! agent alive) and how long until the catalog holds all registrations,
//! as the agent count grows. Expected shape: O(log N) protocol rounds,
//! not O(N).

use vhpc::bench::{banner, print_table};
use vhpc::consul::catalog::{Catalog, ServiceEntry};
use vhpc::consul::ConsulCluster;
use vhpc::sim::SimTime;
use vhpc::util::ids::AgentId;
use vhpc::vnet::addr::Ipv4;

/// Time until the seed agent's member list hits n-1 alive members, and
/// until the catalog lists all n registrations.
fn measure(n: u32) -> (f64, f64) {
    let mut c = ConsulCluster::new(3, 7);
    c.advance_until_leader(SimTime::from_secs(30)).unwrap();
    let t0 = c.now();
    // all agents join via the seed and register their hpc service
    c.agent_join(AgentId::new(0), None, 1);
    for i in 1..n {
        c.agent_join(AgentId::new(i), Some(AgentId::new(0)), 1);
    }
    for i in 0..n {
        let e = ServiceEntry {
            node: format!("node{i:03}"),
            address: Ipv4::new(10, 10, (i >> 8) as u8, (i & 0xff) as u8),
            port: 22,
            slots: 12,
            tags: vec![],
        };
        c.register_service("hpc", &e, SimTime::from_secs(3600));
    }
    let mut gossip_done = None;
    let mut catalog_done = None;
    let deadline = t0 + SimTime::from_secs(600);
    while c.now() < deadline && (gossip_done.is_none() || catalog_done.is_none()) {
        let next = c.now() + SimTime::from_millis(100);
        c.advance(next);
        // FULL convergence: every agent sees every other agent alive
        // (the seed learns instantly — everyone joins through it — so
        // seed-only would be trivially flat).
        if gossip_done.is_none()
            && (0..n).all(|i| {
                c.agent(AgentId::new(i)).unwrap().alive_members().len() == (n - 1) as usize
            })
        {
            gossip_done = Some(c.now().saturating_sub(t0).as_secs_f64());
        }
        if catalog_done.is_none() && Catalog::list(c.kv(), "hpc").len() == n as usize {
            catalog_done = Some(c.now().saturating_sub(t0).as_secs_f64());
        }
    }
    (
        gossip_done.expect("gossip never converged"),
        catalog_done.expect("catalog never complete"),
    )
}

fn main() {
    banner("Fig. 7 — self-registration at scale");
    let ns = [3u32, 8, 16, 32, 64, 128];
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for &n in &ns {
        let (gossip, catalog) = measure(n);
        let log2 = (n as f64).log2();
        rows.push(vec![
            n.to_string(),
            format!("{catalog:.2}s"),
            format!("{gossip:.2}s"),
            format!("{:.2}", gossip / log2),
        ]);
        results.push((n, gossip, catalog));
    }
    print_table(
        &["agents", "catalog complete", "gossip converged", "gossip / log2(n)"],
        &rows,
    );

    // catalog registration goes through raft directly: near-constant
    for &(n, _, catalog) in &results {
        assert!(catalog < 5.0, "catalog at n={n} took {catalog}s");
    }
    // gossip convergence must be sublinear. Compare 32 -> 128 (4x the
    // agents) where join-time floor effects are gone: time must grow by
    // much less than 4x (push-pull anti-entropy bounds the tail).
    let t32 = results.iter().find(|r| r.0 == 32).unwrap().1;
    let t128 = results.iter().find(|r| r.0 == 128).unwrap().1;
    assert!(
        t128 / t32.max(1.0) < 4.0,
        "gossip scales ~linearly or worse: t32={t32:.1}s t128={t128:.1}s"
    );
    assert!(t128 < 60.0, "full convergence too slow at 128: {t128:.1}s");
    println!("\nfig7_registration OK (registration ~flat, gossip ~log n)");
}
