//! Ext-Perf — the trace-overhead gate: the sharded control plane run
//! with the trace bus on must stay within 5% of the untraced run's
//! events/sec.
//!
//! Drives `run_perf_trace` with a trace path, which measures the
//! cluster phase twice on the same deterministic arrival stream —
//! untraced (the gated headline figure) and traced to a JSON-lines
//! file — and refuses to return at all if the traced rerun's counter
//! fingerprint drifts from the untraced one. This bench adds the
//! wall-clock claim on top: buffering, merging and writing the trace
//! is observability, not simulation, and must stay under 5% overhead.
//!
//! Wall-clock 5% gates are noisy on shared runners, so the harness is
//! run `REPEATS` times and the *minimum* observed overhead is gated —
//! a scheduling hiccup in one round cannot fail the build, a real
//! regression shows up in every round. Emits `BENCH_perf.json` in the
//! same schema as `vhpc perf`.

use vhpc::bench::{banner, print_table};
use vhpc::cluster::perf::{perf_spec, render_json, run_perf_trace, PerfOutcome};
use vhpc::config::ClusterSpec;

const MACHINES: u32 = 16;
const JOBS: usize = 20_000;
const TENANTS: u64 = 2_000;
const SHARDS: usize = 4;
const SEED: u64 = 42;
const DURATION_SECS: u64 = 600;
const REPEATS: usize = 2;
const MAX_OVERHEAD_PCT: f64 = 5.0;

fn run_once(round: usize) -> PerfOutcome {
    let mut spec = perf_spec(ClusterSpec::paper_testbed(), MACHINES, SEED);
    let path = std::env::temp_dir().join(format!("vhpc_ext_perf_round{round}.jsonl"));
    spec.trace_path = Some(path.to_string_lossy().into_owned());
    let o = run_perf_trace(spec, JOBS, TENANTS, SHARDS, SEED, DURATION_SECS)
        .expect("perf harness must drain");
    let _ = std::fs::remove_file(&path);
    o
}

fn main() {
    banner(&format!(
        "Ext-Perf — trace overhead gate ({MACHINES} machines, ~{JOBS} jobs / {TENANTS} tenants, \
         {SHARDS} shards, {REPEATS} rounds)"
    ));
    let mut rounds: Vec<PerfOutcome> = Vec::new();
    for round in 0..REPEATS {
        rounds.push(run_once(round));
    }
    let mut rows = Vec::new();
    for (i, o) in rounds.iter().enumerate() {
        rows.push(vec![
            i.to_string(),
            format!("{:.0}k ev/s", o.events_per_sec / 1e3),
            format!("{:.0}k ev/s", o.traced_events_per_sec / 1e3),
            format!("{:+.2}%", o.trace_overhead_pct),
            o.trace_events_written.to_string(),
            o.trace_events_dropped.to_string(),
        ]);
    }
    print_table(
        &["round", "untraced", "traced", "overhead", "events written", "dropped"],
        &rows,
    );

    for o in &rounds {
        assert!(o.trace_events_written > 0, "traced rerun wrote no events");
        assert_eq!(o.trace_events_dropped, 0, "trace sink dropped events");
    }
    // every round produced the identical deterministic run, so the
    // written trace size must agree round to round too
    for o in &rounds[1..] {
        assert_eq!(
            o.trace_events_written, rounds[0].trace_events_written,
            "trace size varied between identical runs"
        );
    }

    let best = rounds
        .iter()
        .min_by(|a, b| a.trace_overhead_pct.total_cmp(&b.trace_overhead_pct))
        .expect("REPEATS >= 1");
    let json = render_json(best);
    std::fs::write("BENCH_perf.json", &json).expect("write BENCH_perf.json");
    println!("wrote BENCH_perf.json (best round)");

    assert!(
        best.trace_overhead_pct < MAX_OVERHEAD_PCT,
        "tracing costs {:.2}% events/sec (limit {MAX_OVERHEAD_PCT}%): \
         untraced {:.0} ev/s vs traced {:.0} ev/s",
        best.trace_overhead_pct,
        best.events_per_sec,
        best.traced_events_per_sec
    );

    println!(
        "\next_perf OK ({:+.2}% trace overhead, {} events traced, fingerprint-neutral)",
        best.trace_overhead_pct, best.trace_events_written
    );
}
