//! Ext-B — auto-scaling response (the abstract's headline feature,
//! quantified).
//!
//! A burst of jobs hits a one-node cluster. We measure time-to-capacity
//! (submit → enough ready nodes), the machine-count trace, and compare
//! against a statically provisioned baseline (min = max = demand) and a
//! no-autoscaler cluster that can never run the burst.

use vhpc::bench::{banner, print_table};
use vhpc::cluster::head::JobKind;
use vhpc::cluster::mix::{bursty_trace, mix_spec, run_job_trace, TraceOutcome};
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::config::ClusterSpec;
use vhpc::sim::SimTime;

struct Outcome {
    time_to_capacity: Option<f64>,
    all_done_at: Option<f64>,
    peak_nodes: usize,
    final_nodes: usize,
    /// `autoscale_reason_*` decision counters at the end of the run.
    reasons: std::collections::BTreeMap<String, u64>,
}

fn run(boot_secs: u64, autoscale: bool, min_nodes: u32) -> Outcome {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = 8;
    spec.machine_spec.boot_time = SimTime::from_secs(boot_secs);
    spec.autoscale.enabled = autoscale;
    spec.autoscale.min_nodes = min_nodes;
    spec.autoscale.max_nodes = 7;
    spec.autoscale.interval = SimTime::from_secs(5);
    spec.autoscale.cooldown = SimTime::from_secs(10);
    spec.autoscale.idle_timeout = SimTime::from_secs(120);
    let mut vc = VirtualCluster::new(spec).unwrap();
    vc.start();
    vc.advance_until(SimTime::from_secs(600), |st| {
        st.node_states.iter().skip(1).filter(|s| **s == vhpc::cluster::vcluster::NodeState::Ready).count()
            >= min_nodes as usize
    });

    // burst: 4 jobs x 36 ranks => needs 3 nodes each
    let t_submit = vc.now();
    for i in 0..4 {
        vc.submit(
            &format!("burst-{i}"),
            36,
            JobKind::Synthetic { duration: SimTime::from_secs(60) },
        );
    }
    let mut time_to_capacity = None;
    let mut all_done_at = None;
    let mut peak = 0usize;
    let deadline = t_submit + SimTime::from_secs(3600);
    while vc.now() < deadline {
        vc.advance(SimTime::from_secs(5));
        let ready = vc.ready_compute_nodes();
        peak = peak.max(ready);
        if time_to_capacity.is_none() && vc.state.head.slots_available() >= 36 {
            time_to_capacity = Some(vc.now().saturating_sub(t_submit).as_secs_f64());
        }
        if vc.completed_jobs().len() == 4 {
            all_done_at = Some(vc.now().saturating_sub(t_submit).as_secs_f64());
            break;
        }
    }
    // drain the idle period to observe scale-down
    vc.advance(SimTime::from_secs(400));
    let reasons = vc
        .metrics()
        .counters_snapshot()
        .into_iter()
        .filter(|(k, _)| k.starts_with("autoscale_reason_"))
        .collect();
    Outcome {
        time_to_capacity,
        all_done_at,
        peak_nodes: peak,
        final_nodes: vc.ready_compute_nodes(),
        reasons,
    }
}

/// Run the canonical bursty mix (36-rank wide jobs bracketing narrow
/// ones) with the head capped at `max_concurrent` jobs (1 = the seed's
/// serial scheduler).
fn run_mix(max_concurrent: usize) -> TraceOutcome {
    let spec = mix_spec(SimTime::from_secs(30));
    let (outcome, _) =
        run_job_trace(spec, &bursty_trace(36, 10), max_concurrent, 36, 3600).expect("mix trace");
    outcome
}

fn main() {
    banner("Ext-B — autoscaler response to a 4x36-rank burst (8 machines)");
    let configs: Vec<(String, u64, bool, u32)> = vec![
        ("autoscale, 90s boot".into(), 90, true, 1),
        ("autoscale, 30s boot".into(), 30, true, 1),
        ("static 3 nodes (pre-provisioned)".into(), 90, false, 3),
        ("static 1 node (no autoscaler)".into(), 90, false, 1),
    ];
    let mut rows = Vec::new();
    let mut outcomes = Vec::new();
    for (name, boot, auto_on, min) in &configs {
        let o = run(*boot, *auto_on, *min);
        rows.push(vec![
            name.clone(),
            o.time_to_capacity.map(|t| format!("{t:.0}s")).unwrap_or("never".into()),
            o.all_done_at.map(|t| format!("{t:.0}s")).unwrap_or("never".into()),
            o.peak_nodes.to_string(),
            o.final_nodes.to_string(),
        ]);
        outcomes.push(o);
    }
    print_table(
        &["configuration", "time to 36 slots", "burst drained", "peak nodes", "nodes after idle"],
        &rows,
    );

    // shape assertions
    let auto90 = &outcomes[0];
    let auto30 = &outcomes[1];
    let static3 = &outcomes[2];
    let static1 = &outcomes[3];
    assert!(auto90.time_to_capacity.is_some(), "autoscaler must reach capacity");
    assert!(auto90.all_done_at.is_some(), "autoscaler must drain the burst");
    // capacity time is dominated by provisioning latency (boot time)
    assert!(
        auto30.time_to_capacity.unwrap() < auto90.time_to_capacity.unwrap(),
        "faster boot must reach capacity sooner"
    );
    // static pre-provisioned runs immediately; autoscale pays boot latency
    assert!(static3.time_to_capacity.unwrap() <= auto90.time_to_capacity.unwrap());
    // without autoscaling and only 1 node, 36-rank jobs can never run
    assert!(static1.all_done_at.is_none(), "1 static node must starve the burst");
    // autoscaler returns to min after idleness
    assert_eq!(auto90.final_nodes, 1, "must scale back to min after idle");

    // every decision is accounted for by reason: the burst forces
    // queued-demand scale-ups, the idle drain forces a low-util
    // scale-down, and a disabled autoscaler never decides at all
    for o in [auto90, auto30] {
        assert!(
            o.reasons.get("autoscale_reason_queued_demand").copied().unwrap_or(0) > 0,
            "burst must register queued-demand decisions, got {:?}",
            o.reasons
        );
        assert!(
            o.reasons.get("autoscale_reason_low_util").copied().unwrap_or(0) > 0,
            "idle drain must register a low-util scale-down, got {:?}",
            o.reasons
        );
    }
    // boot latency (90s) spans several 5s policy ticks after the first
    // scale-up: the cooldown must be seen holding at least once
    assert!(
        auto90.reasons.get("autoscale_reason_cooldown_held").copied().unwrap_or(0) > 0,
        "slow boot must register cooldown-held decisions, got {:?}",
        auto90.reasons
    );
    assert!(
        static1.reasons.is_empty() && static3.reasons.is_empty(),
        "a disabled autoscaler must emit no reason counters: {:?} / {:?}",
        static1.reasons,
        static3.reasons
    );

    banner("Ext-B2 — mixed-width trace: serial (seed) head vs slot-aware backfill");
    let serial = run_mix(1);
    let concurrent = run_mix(usize::MAX);
    print_table(
        &["scheduler", "mean queue wait", "makespan", "peak jobs", "backfills"],
        &[
            vec![
                "serial (1 job)".into(),
                format!("{:.1}s", serial.mean_wait),
                format!("{:.1}s", serial.makespan),
                serial.peak_concurrency.to_string(),
                serial.backfill_starts.to_string(),
            ],
            vec![
                "concurrent".into(),
                format!("{:.1}s", concurrent.mean_wait),
                format!("{:.1}s", concurrent.makespan),
                concurrent.peak_concurrency.to_string(),
                concurrent.backfill_starts.to_string(),
            ],
        ],
    );
    assert!(concurrent.peak_concurrency >= 3, "must overlap >= 3 jobs");
    assert!(
        concurrent.mean_wait < serial.mean_wait,
        "concurrent scheduler must cut mean queue wait ({:.1}s vs {:.1}s)",
        concurrent.mean_wait,
        serial.mean_wait
    );
    assert!(concurrent.makespan < serial.makespan, "overlap must cut makespan");

    println!(
        "\next_autoscale OK (reaches capacity, drains burst, scales back, backfill cuts waits)"
    );
}
