//! Ext-HA — head-node failover: MTTR vs lease TTL, WAL replay
//! throughput vs log length, and the snapshot bound on takeover
//! replay.
//!
//! Three sections:
//!  1. failover MTTR on the canonical mix as the leadership-lease TTL
//!     shrinks (detection latency ≈ lock_ttl + standby poll);
//!  2. pure replay throughput: rebuild a head from synthetic WALs of
//!     growing length and measure wall-clock events/second;
//!  3. the snapshot bound: the same crashed scenario with and without
//!     snapshotting — the takeover's replayed-event count stays flat
//!     with snapshots on while the raw log keeps growing.

use std::time::Instant;
use vhpc::bench::{banner, print_table};
use vhpc::cluster::head::{Head, JobKind, JobSpec};
use vhpc::cluster::mix::{bursty_trace, mix_spec};
use vhpc::ha::{run_ha_trace, wal, HaOutcome};
use vhpc::sim::SimTime;
use vhpc::util::ids::JobId;

const JOBS: usize = 10;
const DEADLINE_SECS: u64 = 3600;

fn run(lock_ttl_secs: u64, snapshot_every: u64, crash: bool) -> HaOutcome {
    let mut spec = mix_spec(SimTime::from_secs(30));
    spec.ha.lock_ttl = SimTime::from_secs(lock_ttl_secs);
    spec.ha.snapshot_every = snapshot_every;
    let trace = bursty_trace(24, JOBS);
    let crash_at = if crash { Some(SimTime::from_secs(45)) } else { None };
    let (o, _vc) = run_ha_trace(spec, &trace, crash_at, 36, DEADLINE_SECS)
        .expect("ha trace must drain");
    o
}

/// A synthetic WAL: `n` submit→dispatch→accrue→complete cycles driven
/// through a journaling head, exactly the event mix a real run logs.
fn synthetic_wal(n: usize) -> Vec<wal::WalEvent> {
    let mut head = Head::new();
    head.enable_journal();
    head.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
    let mut log = Vec::new();
    for i in 0..n as u32 {
        let t = SimTime::from_secs(2 * i as u64);
        head.submit(
            JobSpec {
                id: JobId::new(i),
                name: format!("wal-{i}"),
                ranks: 8,
                kind: JobKind::Synthetic { duration: SimTime::from_secs(2) },
                priority: 0,
                tenant: (i % 5) as u64,
            },
            t,
        );
        head.start_next(t).unwrap();
        if let Some(rec) = head.running.get_mut(&JobId::new(i)) {
            rec.planned_duration = Some(SimTime::from_secs(2));
        }
        log.append(&mut head.take_journal());
        log.push(wal::WalEvent::Launched {
            at: t,
            id: JobId::new(i),
            attempt: 0,
            planned: SimTime::from_secs(2),
            result: None,
        });
        let done = t + SimTime::from_secs(2);
        head.accrue_usage(done);
        if let Some(mut rec) = head.finish(JobId::new(i)) {
            rec.state = vhpc::cluster::head::JobState::Done { started: t, finished: done };
            head.completed.push(rec);
        }
        log.append(&mut head.take_journal());
        log.push(wal::WalEvent::Completed { at: done, id: JobId::new(i), attempt: 0 });
    }
    log
}

fn main() {
    banner("Ext-HA1 — failover MTTR vs leadership-lease TTL (8 machines, 10-job mix)");
    let mut rows = Vec::new();
    for ttl in [2u64, 5, 10] {
        let o = run(ttl, 256, true);
        assert_eq!(o.takeovers, 1, "ttl {ttl}: the standby must take over");
        assert_eq!(o.jobs_completed, JOBS, "ttl {ttl}: no job may be lost");
        assert_eq!(o.requeues, 0, "failover must not charge retry budget");
        rows.push(vec![
            format!("{ttl}s"),
            format!("{:.1}s", o.failover_mean),
            format!("{}", o.wal_appends),
            format!("{}", o.replayed_events),
            format!("{:.0}s", o.makespan),
        ]);
    }
    print_table(&["lease ttl", "failover MTTR", "wal appends", "replayed", "makespan"], &rows);

    banner("Ext-HA2 — WAL replay throughput vs log length");
    let mut rows = Vec::new();
    for n in [500usize, 2_000, 8_000] {
        let log = synthetic_wal(n);
        let events = log.len();
        // encode/decode round-trip included: that is what a real
        // takeover pays reading the KV store
        let encoded: Vec<String> = log.iter().map(|e| e.encode()).collect();
        let t0 = Instant::now();
        let decoded: Vec<wal::WalEvent> = encoded
            .iter()
            .map(|l| wal::WalEvent::decode(l).expect("own encoding must decode"))
            .collect();
        let mut head = Head::new();
        head.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        let replayed = wal::replay(&mut head, &decoded);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(replayed, events);
        assert_eq!(head.completed.len(), n, "every logged job must replay to Done");
        rows.push(vec![
            n.to_string(),
            events.to_string(),
            format!("{:.1}ms", dt * 1e3),
            format!("{:.0}k ev/s", events as f64 / dt / 1e3),
        ]);
    }
    print_table(&["jobs", "wal events", "replay time", "throughput"], &rows);

    banner("Ext-HA3 — snapshotting bounds takeover replay");
    let unbounded = run(5, 0, true); // snapshots off
    let bounded = run(5, 16, true); // snapshot every 16 appends
    assert_eq!(unbounded.jobs_completed, JOBS);
    assert_eq!(bounded.jobs_completed, JOBS);
    assert_eq!(unbounded.snapshots, 0);
    assert!(bounded.snapshots >= 1, "the 16-append cadence must snapshot");
    assert!(
        bounded.replayed_events < unbounded.replayed_events,
        "snapshots must shrink the replay tail: {} !< {}",
        bounded.replayed_events,
        unbounded.replayed_events
    );
    print_table(
        &["snapshot cadence", "wal appends", "snapshots", "replayed at takeover"],
        &[
            vec![
                "never".into(),
                unbounded.wal_appends.to_string(),
                unbounded.snapshots.to_string(),
                unbounded.replayed_events.to_string(),
            ],
            vec![
                "every 16".into(),
                bounded.wal_appends.to_string(),
                bounded.snapshots.to_string(),
                bounded.replayed_events.to_string(),
            ],
        ],
    );

    // determinism: two identical crashed runs, identical fingerprints
    let a = run(5, 16, true);
    let b = run(5, 16, true);
    assert_eq!(a.fingerprint, b.fingerprint, "same-seed HA runs diverged");

    println!("\next_ha OK (lease-bounded MTTR, lossless failover, snapshot-bounded replay)");
}
