//! Ext-T — multi-tenant fair share: population scaling, fairness under
//! fifo vs priority vs fairshare, and same-seed determinism.
//!
//! One seeded open-loop arrival stream (power-law tenant rates, diurnal
//! swing, priority-2 campaign bursts) drives the 8-machine mix cluster
//! for 1500 virtual seconds, then drains. Three sections:
//!
//! * **T1 — population scale.** The same aggregate load spread over 10,
//!   1k and 100k tenants. The generator samples the mixture (O(1) per
//!   arrival), so the 100k run costs the same as the 10-tenant run —
//!   no per-tenant state is ever materialized for idle users.
//! * **T2 — policy comparison at 1k tenants.** Jain's fairness index
//!   over per-tenant mean slowdown, fifo vs priority vs easy vs
//!   fairshare, same seed. Campaign bursts make the head tenants hog:
//!   priority serves the bursts first (worst fairness), fifo lets the
//!   tail wait out the bursts, fairshare sinks the hogs behind the
//!   tail's fresh tenants — strictly the highest index.
//! * **T3 — determinism.** Two same-seed fairshare runs must produce
//!   byte-identical arrival streams, metric counters and (bitwise)
//!   fairness figures.

use vhpc::bench::{banner, print_table};
use vhpc::cluster::mix::{mix_spec, run_tenant_trace, TenantTraceOutcome};
use vhpc::cluster::policy::{PolicyKind, SchedulePolicy};
use vhpc::sim::SimTime;
use vhpc::tenancy::{PopulationSpec, TenantQuotas};

const SEED: u64 = 2026;
const DURATION_SECS: u64 = 1500;
const DEADLINE_SECS: u64 = 9000;

fn population(tenants: u64) -> PopulationSpec {
    let mut pop = PopulationSpec::new(tenants, SEED);
    // ~65% mean utilization on the mix cluster, with diurnal peaks and
    // campaign bursts pushing past capacity so queues actually form
    pop.rate_per_sec = 0.15;
    pop.diurnal_period = SimTime::from_secs(1000);
    pop
}

fn run(tenants: u64, kind: PolicyKind) -> TenantTraceOutcome {
    let spec = mix_spec(SimTime::from_secs(30));
    let (outcome, vc) = run_tenant_trace(
        spec,
        population(tenants),
        SchedulePolicy::new(kind),
        TenantQuotas::default(),
        DURATION_SECS,
        DEADLINE_SECS,
    )
    .expect("tenant trace must drain");
    assert!(
        vc.state.head.overbooked_hosts().is_empty(),
        "tenancy load must never double-book a slot"
    );
    outcome
}

fn row(label: &str, o: &TenantTraceOutcome) -> Vec<String> {
    vec![
        label.to_string(),
        o.jobs_submitted.to_string(),
        o.tenants_seen.to_string(),
        format!("{:.1}s", o.mean_wait),
        format!("{:.1}s", o.p99_wait),
        format!("{:.2}", o.mean_slowdown),
        format!("{:.4}", o.fairness_slowdown),
        format!("{:.0}s", o.makespan),
    ]
}

const HEADERS: [&str; 8] = [
    "scenario",
    "jobs",
    "active tenants",
    "mean wait",
    "p99 wait",
    "slowdown",
    "Jain(slowdown)",
    "makespan",
];

fn main() {
    // ---- T1: the same load over 10 / 1k / 100k tenants (fairshare)
    banner("Ext-T1 — population scale (fairshare, same aggregate load)");
    let scales = [10u64, 1_000, 100_000];
    let mut rows = Vec::new();
    for &n in &scales {
        let o = run(n, PolicyKind::FairShare);
        assert_eq!(
            o.jobs_completed + o.jobs_failed,
            o.jobs_submitted,
            "{n}-tenant run must account for every submission"
        );
        assert!(o.jobs_submitted > 100, "1500s at ~0.15/s must submit real load");
        assert!(
            o.tenants_seen <= n as usize,
            "cannot see more tenants than the population"
        );
        rows.push(row(&format!("{n} tenants"), &o));
    }
    print_table(&HEADERS, &rows);

    // ---- T2: fairness under fifo vs priority vs easy vs fairshare
    banner("Ext-T2 — policy fairness at 1k tenants (same seeded stream)");
    let fifo = run(1_000, PolicyKind::Fifo);
    let priority = run(1_000, PolicyKind::Priority);
    let easy = run(1_000, PolicyKind::Easy);
    let fair = run(1_000, PolicyKind::FairShare);
    print_table(
        &HEADERS,
        &[
            row("fifo", &fifo),
            row("priority", &priority),
            row("easy", &easy),
            row("fairshare", &fair),
        ],
    );
    // identical stream across policies: the comparison is apples to apples
    assert_eq!(fifo.arrivals_fingerprint, fair.arrivals_fingerprint);
    assert_eq!(priority.arrivals_fingerprint, fair.arrivals_fingerprint);
    // the workload must actually congest, or fairness is vacuous
    assert!(
        fifo.mean_wait > 1.0,
        "the stream must form queues under fifo: mean wait {:.2}s",
        fifo.mean_wait
    );
    assert!(
        fair.fairness_slowdown > fifo.fairness_slowdown,
        "fairshare must beat fifo on per-tenant slowdown fairness: {:.4} vs {:.4}",
        fair.fairness_slowdown,
        fifo.fairness_slowdown
    );
    assert!(
        fair.fairness_slowdown > priority.fairness_slowdown,
        "fairshare must beat priority on per-tenant slowdown fairness: {:.4} vs {:.4}",
        fair.fairness_slowdown,
        priority.fairness_slowdown
    );

    // ---- T3: same seed, same everything
    banner("Ext-T3 — same seed, same stream, same metrics (determinism)");
    let a = run(1_000, PolicyKind::FairShare);
    let b = run(1_000, PolicyKind::FairShare);
    assert_eq!(
        a.arrivals_fingerprint, b.arrivals_fingerprint,
        "same-seed arrival streams diverged"
    );
    assert_eq!(a.fingerprint, b.fingerprint, "same-seed metric counters diverged");
    assert_eq!(
        a.fairness_slowdown.to_bits(),
        b.fairness_slowdown.to_bits(),
        "fairness must replay bit-identically"
    );
    assert_eq!(a.mean_wait.to_bits(), b.mean_wait.to_bits());
    println!(
        "two seed-{SEED} runs: identical stream ({:016x}), {} counters, Jain {:.4}",
        a.arrivals_fingerprint,
        a.fingerprint.len(),
        a.fairness_slowdown
    );

    println!(
        "\next_tenancy OK (scales 10 -> 100k tenants, fairshare maximizes Jain, deterministic)"
    );
}
