//! Ext-F — self-healing under fault injection: MTTR, wasted work and
//! goodput as the crash rate rises, plus a same-seed determinism check.
//!
//! The canonical bursty job mix (wide 24-rank jobs bracketing narrow
//! ones, as in `ext_autoscale`) runs on the 8-machine mix cluster while
//! machines crash at per-machine-MTBF-drawn times. The recovery
//! pipeline must drain every trace: requeued jobs rerun, the autoscaler
//! boots replacements, and the same seed must replay identically.
//!
//! Note on the "wasted" column: synthetic jobs checkpoint continuously
//! (requeues credit the full elapsed duration), so their waste is 0 by
//! construction and the column stays flat here — it becomes nonzero on
//! Jacobi traces, where restarts round down to the last residual
//! checkpoint. MTTR and makespan inflation are the fault-cost signals
//! for synthetic traces.

use vhpc::bench::{banner, print_table};
use vhpc::cluster::mix::{bursty_trace, mix_spec};
use vhpc::faults::{run_chaos_trace, ChaosOutcome, FaultPlan};
use vhpc::sim::SimTime;

const SEED: u64 = 2026;
const JOBS: usize = 12;
const DEADLINE_SECS: u64 = 3600;

fn run(mtbf_secs: Option<u64>) -> ChaosOutcome {
    let spec = mix_spec(SimTime::from_secs(30));
    let machines = spec.machines;
    let trace = bursty_trace(24, JOBS);
    let plan = match mtbf_secs {
        Some(mtbf) => FaultPlan::from_mtbf(
            SEED,
            machines,
            SimTime::from_secs(mtbf),
            SimTime::from_secs(DEADLINE_SECS),
        ),
        None => FaultPlan::default(),
    };
    let (outcome, _vc) = run_chaos_trace(spec, &trace, &plan, 36, 5, DEADLINE_SECS)
        .expect("chaos trace must drain");
    outcome
}

fn main() {
    banner("Ext-F — recovery vs fault rate (8 machines, 12-job bursty mix)");
    let rates: Vec<(String, Option<u64>)> = vec![
        ("no faults".into(), None),
        ("mtbf 1200s/machine".into(), Some(1200)),
        ("mtbf 600s/machine".into(), Some(600)),
        ("mtbf 240s/machine".into(), Some(240)),
    ];
    let mut rows = Vec::new();
    let mut outcomes = Vec::new();
    for (name, mtbf) in &rates {
        let o = run(*mtbf);
        rows.push(vec![
            name.clone(),
            o.machines_killed.to_string(),
            format!("{}/{}", o.jobs_completed, o.jobs_submitted),
            o.requeues.to_string(),
            format!("{:.1}s", o.mttr_mean),
            format!("{:.1}s", o.wasted_seconds),
            format!("{:.1}", o.goodput),
            format!("{:.0}s", o.makespan),
        ]);
        outcomes.push(o);
    }
    print_table(
        &[
            "fault rate",
            "kills",
            "done",
            "requeues",
            "MTTR mean",
            "wasted",
            "goodput",
            "makespan",
        ],
        &rows,
    );

    // shape assertions
    let clean = &outcomes[0];
    assert_eq!(clean.jobs_completed, JOBS, "fault-free run must complete everything");
    assert_eq!(clean.requeues, 0);
    assert_eq!(clean.machines_killed, 0);
    assert_eq!(clean.mttr_max, 0.0, "no faults, no repairs");
    for o in &outcomes {
        assert_eq!(
            o.jobs_completed + o.jobs_abandoned,
            JOBS,
            "every job must be accounted for"
        );
        assert!(o.mttr_max.is_finite(), "MTTR must be finite");
        assert!(o.goodput > 0.0);
    }
    // light chaos must not lose jobs: the retry budget absorbs it
    let light = &outcomes[1];
    assert_eq!(
        light.jobs_completed, JOBS,
        "every job must eventually complete under light chaos"
    );

    banner("Ext-F2 — same seed, same chaos (determinism)");
    let a = run(Some(600));
    let b = run(Some(600));
    assert_eq!(
        a.fingerprint, b.fingerprint,
        "two same-seed runs diverged: injection is not deterministic"
    );
    assert_eq!(a.requeues, b.requeues);
    assert_eq!(a.makespan, b.makespan);
    println!(
        "two seed-{SEED} runs: identical fingerprints ({} counters), {} requeues, makespan {:.0}s",
        a.fingerprint.len(),
        a.requeues,
        a.makespan
    );

    println!("\next_faults OK (drains under chaos, finite MTTR, deterministic replay)");
}
